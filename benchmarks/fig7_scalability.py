"""Fig. 7 analog: speedup vs dataset scale (Hospital, LR + GB)."""
from __future__ import annotations

from benchmarks.common import NOOPT, build_query, make_dataset, run_variant, train_model

SIZES = [10_000, 50_000, 200_000, 800_000]


def run(quick: bool = False):
    rows = []
    sizes = SIZES[:2] if quick else SIZES
    for kind in ("lr", "gb"):
        train, _ = make_dataset("hospital", 4096)
        pipe = train_model(train, kind)
        for n in sizes:
            _, infer = make_dataset("hospital", n)
            q = build_query(infer, pipe)
            t_noopt = run_variant(q, infer.tables, **NOOPT)
            t_opt = min(
                run_variant(q, infer.tables, transform=t)
                for t in ("none", "sql", "dnn")
            )
            rows.append({"model": kind, "rows": n, "noopt_s": t_noopt,
                         "raven_s": t_opt, "speedup": t_noopt / t_opt})
            print(f"fig7,{kind},{n},{t_noopt:.3f},{t_opt:.3f},{t_noopt/t_opt:.2f}x")
    return rows


if __name__ == "__main__":
    print("fig7,model,rows,noopt_s,raven_s,speedup")
    run()
