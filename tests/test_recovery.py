"""Crash-safe registry recovery + the automated rollback drill.

The acceptance contract: a process can die (``kill -9``) between any two
requests and a fresh session over the same tables and cache dir rebuilds
the *entire* serving topology — published versions with their histories,
live/shadow/split pointers, the rollback log, every served route with its
bucket ladder — and answers previously-seen shapes with zero new XLA
traces and bitwise-identical results. Rollback rides the cutover
machinery: zero dropped requests, zero retraces.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

import repro as raven
from repro.analysis.registry_check import check_registry
from repro.data.datasets import make_hospital
from repro.errors import RecoveryError

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SQL = "SELECT * FROM PREDICT(model='risk', data=patients) AS p"


def _batch(n: int, seed: int) -> dict[str, np.ndarray]:
    return make_hospital(n, seed=seed).tables["patients"]


def _sums(db, prep) -> list[float]:
    out = []
    for i, n in enumerate((128, 256)):
        req = prep.submit(_batch(n, seed=40 + i))
        db.flush()
        out.append(float(np.sum(req.wait(timeout=60.0)["score"])))
    return out


def _topology(db) -> dict:
    snap = db.models.snapshot()["risk"]
    return {
        "live": snap["live"],
        "shadow": snap["shadow"],
        "split": snap["split"],
        "routes": sorted(snap["routes"]),
        "versions": [(v["version"], v["state"]) for v in snap["versions"]],
    }


# -- in-process A/B ----------------------------------------------------------

def test_recover_restores_topology_and_results(
    tmp_path, hospital, hospital_dt, hospital_lr
):
    opts = raven.ConnectOptions(cache_dir=str(tmp_path / "c"))
    db = raven.connect(hospital.tables, stats="auto", options=opts)
    db.models.publish("risk", hospital_dt)
    prep = db.sql(SQL).prepare(transform="sql")
    prep.serve("q")
    sums_a = _sums(db, prep)  # v1 results, before any split
    db.models.publish("risk", hospital_lr, warm="sync")
    db.models.shadow("risk", 2)
    db.models.split("risk", {2: 0.25})
    topo_a = _topology(db)
    db.artifact_store.drain()
    db.close()

    db2 = raven.connect(hospital.tables, stats="auto", options=opts)
    try:
        counts = db2.recover()
        assert counts["recovered"]
        assert counts["models"] == 1 and counts["versions"] == 2
        assert counts["routes"] == 1 and counts["skipped"] == []
        assert _topology(db2) == topo_a
        assert check_registry(db2) == []
        # route traffic deterministically back to v1 for the equality leg
        # (shadow stays: mirrored, never returned)
        db2.models.split("risk", {})
        traces = db2.cache_stats()["traces"]
        prep2 = db2.sql(SQL).prepare(transform="sql")
        prep2.serve("q")
        assert _sums(db2, prep2) == sums_a
        # previously-seen shapes replay warm: the ladder was restored and
        # the stage programs came off disk
        assert db2.cache_stats()["traces"] == traces
    finally:
        db2.close()


def test_recover_error_paths(tmp_path, hospital, hospital_dt):
    db = raven.connect(hospital.tables, stats="auto")
    with pytest.raises(RecoveryError, match="artifact store"):
        db.recover()
    db.close()

    opts = raven.ConnectOptions(cache_dir=str(tmp_path / "c"))
    db = raven.connect(hospital.tables, stats="auto", options=opts)
    assert db.recover() == {"recovered": False}  # nothing journaled yet
    db.models.publish("risk", hospital_dt)
    with pytest.raises(RecoveryError, match="fresh"):
        db.recover()  # refuses to clobber a non-empty registry
    db.close()


# -- rollback drill: zero dropped, zero retraced -----------------------------

def test_rollback_drill_zero_drop_zero_retrace(
    tmp_path, hospital, hospital_dt, hospital_lr
):
    opts = raven.ConnectOptions(cache_dir=str(tmp_path / "c"))
    db = raven.connect(hospital.tables, stats="auto", options=opts)
    try:
        db.models.publish("risk", hospital_dt)
        prep = db.sql(SQL).prepare(transform="sql")
        prep.serve("q")
        sums_v1 = _sums(db, prep)
        db.models.publish("risk", hospital_lr, warm="sync")
        db.models.cutover("risk", 2)
        _sums(db, prep)  # v2 serves; handles survived the swap
        recompiles = db.cache_stats()["server"]["recompiles"]

        restored = db.models.rollback("risk", reason="drill")
        assert restored.version == 1 and restored.state == "live"
        assert _sums(db, prep) == sums_v1  # v1 serves again, bitwise
        assert db.cache_stats()["server"]["recompiles"] == recompiles

        snap = db.models.snapshot()["risk"]
        assert snap["live"] == 1
        (rb,) = snap["rollbacks"]
        assert rb["from"] == 2 and rb["to"] == 1 and rb["reason"] == "drill"
        events = {
            v["version"]: v["events"] for v in snap["versions"]
        }
        assert any("rolled back" in e for e in events[2])
        assert any("restored live by rollback" in e for e in events[1])
        assert "rolled back" in prep.explain()
        assert check_registry(db) == []
    finally:
        db.close()


# -- the acceptance path: kill -9, then recover in a fresh process -----------

_CHILD_A = """
import json, os, signal, sys
import numpy as np
import repro as raven
from repro.data.datasets import make_hospital
from repro.ml.pipeline import load_pipeline


def main():
    cache_dir, pipe1, pipe2 = sys.argv[1], sys.argv[2], sys.argv[3]
    ds = make_hospital(512, seed=7)
    db = raven.connect(
        ds.tables, stats="auto",
        options=raven.ConnectOptions(cache_dir=cache_dir),
    )
    db.models.publish("risk", load_pipeline(pipe1))
    prep = db.sql(
        "SELECT * FROM PREDICT(model='risk', data=patients) AS p"
    ).prepare(transform="sql")
    prep.serve("q")
    sums = []
    for i, n in enumerate((128, 256)):
        req = prep.submit(make_hospital(n, seed=40 + i).tables["patients"])
        db.flush()
        sums.append(float(np.sum(req.wait(timeout=60.0)["score"])))
    db.models.publish("risk", load_pipeline(pipe2), warm="sync")
    db.models.shadow("risk", 2)
    snap = db.models.snapshot()["risk"]
    db.artifact_store.drain()  # stage programs must reach disk pre-crash
    print(json.dumps({
        "sums": sums,
        "topology": {
            "live": snap["live"], "shadow": snap["shadow"],
            "split": snap["split"], "routes": sorted(snap["routes"]),
            "versions": [
                (v["version"], v["state"]) for v in snap["versions"]
            ],
        },
    }))
    sys.stdout.flush()
    os.kill(os.getpid(), signal.SIGKILL)  # no close(), no atexit — a crash


main()
"""

_CHILD_B = """
import json, sys
import numpy as np
import repro as raven
from repro.analysis.registry_check import check_registry
from repro.data.datasets import make_hospital


def main():
    cache_dir = sys.argv[1]
    ds = make_hospital(512, seed=7)
    db = raven.connect(
        ds.tables, stats="auto",
        options=raven.ConnectOptions(cache_dir=cache_dir),
    )
    counts = db.recover()
    snap = db.models.snapshot()["risk"]
    violations = [str(v) for v in check_registry(db)]
    traces0 = db.cache_stats()["traces"]
    prep = db.sql(
        "SELECT * FROM PREDICT(model='risk', data=patients) AS p"
    ).prepare(transform="sql")
    prep.serve("q")
    sums = []
    for i, n in enumerate((128, 256)):
        req = prep.submit(make_hospital(n, seed=40 + i).tables["patients"])
        db.flush()
        sums.append(float(np.sum(req.wait(timeout=60.0)["score"])))
    print(json.dumps({
        "counts": counts,
        "sums": sums,
        "violations": violations,
        "new_traces": db.cache_stats()["traces"] - traces0,
        "topology": {
            "live": snap["live"], "shadow": snap["shadow"],
            "split": snap["split"], "routes": sorted(snap["routes"]),
            "versions": [
                (v["version"], v["state"]) for v in snap["versions"]
            ],
        },
    }))
    db.close()


main()
"""


def _spawn(script_path: str, *argv: str, want_signal=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, script_path, *argv],
        capture_output=True, text=True, timeout=300, env=env, cwd=REPO,
    )
    if want_signal is not None:
        assert proc.returncode == -want_signal, (
            proc.returncode, proc.stderr[-2000:],
        )
    else:
        assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sigkill_crash_recovery_across_processes(
    tmp_path, hospital_dt, hospital_lr
):
    from repro.ml.pipeline import save_pipeline

    cache = str(tmp_path / "c")
    pipe1 = str(tmp_path / "p1.npz")
    pipe2 = str(tmp_path / "p2.npz")
    save_pipeline(hospital_dt, pipe1)
    save_pipeline(hospital_lr, pipe2)
    a_path = str(tmp_path / "child_a.py")
    b_path = str(tmp_path / "child_b.py")
    with open(a_path, "w") as f:
        f.write(_CHILD_A)
    with open(b_path, "w") as f:
        f.write(_CHILD_B)

    a = _spawn(a_path, cache, pipe1, pipe2, want_signal=signal.SIGKILL)
    b = _spawn(b_path, cache)

    assert b["counts"]["recovered"]
    assert b["counts"]["routes"] == 1 and b["counts"]["skipped"] == []
    assert b["topology"] == a["topology"]
    assert b["sums"] == a["sums"]
    assert b["violations"] == []
    assert b["new_traces"] == 0
