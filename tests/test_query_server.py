"""Prediction-query serving layer: fingerprints, caching, bucketed padding,
micro-batching (the cached hot path the paper's optimize-once model implies)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.ir import TableStats, plan_fingerprint as logical_fingerprint
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.data.datasets import make_hospital
from repro.relational.engine import (
    PLAN_CACHE_STATS,
    clear_plan_cache,
    compile_plan,
    execute_plan,
    plan_fingerprint,
)
from repro.serve import PredictionQueryServer, row_bucket
from repro.sql.parser import parse_prediction_query

SQL_STAR = "SELECT * FROM PREDICT(model='m', data=patients) AS p WHERE score >= 0.6"
SQL_AGG = (
    "SELECT COUNT(*), AVG(score) FROM PREDICT(model='m', data=patients) AS p "
    "WHERE score >= 0.6"
)


def _query(hospital, pipe, sql=SQL_STAR):
    stats = {"patients": TableStats.of(hospital.tables["patients"])}
    return parse_prediction_query(sql, {"m": pipe}, hospital.tables, stats=stats)


@pytest.fixture(scope="module")
def dt_query(hospital, hospital_dt):
    return _query(hospital, hospital_dt)


def _optimize(query, **opts):
    return RavenOptimizer(options=OptimizerOptions(**opts)).optimize(query)[0]


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------


def test_fingerprint_stable_across_plan_objects(hospital, dt_query):
    plan_a = _optimize(dt_query, transform="sql")
    plan_b = _optimize(dt_query, transform="sql")
    assert plan_a is not plan_b
    assert plan_fingerprint(plan_a) == plan_fingerprint(plan_b)
    # logical plans too (the server's optimized-plan cache key)
    assert logical_fingerprint(dt_query.plan) == logical_fingerprint(
        dt_query.copy().plan
    )


def test_fingerprint_sensitive_to_content(hospital, dt_query, hospital_gb):
    sql_plan = _optimize(dt_query, transform="sql")
    none_plan = _optimize(dt_query, transform="none")
    assert plan_fingerprint(sql_plan) != plan_fingerprint(none_plan)
    other = _optimize(_query(hospital, hospital_gb), transform="sql")
    assert plan_fingerprint(sql_plan) != plan_fingerprint(other)
    # perturbing one model weight must change the hash (pipeline copies share
    # the ensemble arrays, so swap in a deep-copied ensemble before editing)
    q2 = dt_query.copy()
    node = q2.predict_nodes()[0].pipeline.model_nodes()[0]
    ens = node.attrs["ensemble"].copy()
    ens.leaf_value[0] += 1.0
    node.attrs["ensemble"] = ens
    assert logical_fingerprint(q2.plan) != logical_fingerprint(dt_query.plan)


# ---------------------------------------------------------------------------
# Engine compiled-plan cache
# ---------------------------------------------------------------------------


def test_compile_plan_cache_hit_accounting(hospital, dt_query):
    clear_plan_cache()
    plan_a = _optimize(dt_query, transform="sql")
    plan_b = _optimize(dt_query, transform="sql")
    c1 = compile_plan(plan_a)
    assert (PLAN_CACHE_STATS.hits, PLAN_CACHE_STATS.misses) == (0, 1)
    c2 = compile_plan(plan_b)  # distinct object, identical content
    assert c2 is c1
    assert (PLAN_CACHE_STATS.hits, PLAN_CACHE_STATS.misses) == (1, 1)
    assert compile_plan(plan_a, cache=False) is not c1  # opt-out path


def test_execute_plan_reuses_compiled_stages(hospital, dt_query):
    clear_plan_cache()
    plan = _optimize(dt_query, transform="sql")
    out1 = execute_plan(plan, hospital.tables)
    traces_after_first = PLAN_CACHE_STATS.traces
    assert traces_after_first >= 1
    out2 = execute_plan(plan, hospital.tables)
    assert PLAN_CACHE_STATS.traces == traces_after_first  # no re-jit per call
    a, b = out1.to_numpy(), out2.to_numpy()
    for k in a:
        np.testing.assert_allclose(a[k], b[k])


# ---------------------------------------------------------------------------
# Padded-bucket execution
# ---------------------------------------------------------------------------


def test_row_bucket():
    assert row_bucket(1) == 64
    assert row_bucket(64) == 64
    assert row_bucket(65) == 128
    assert row_bucket(1000) == 1024
    assert row_bucket(0, min_bucket=8) == 8


@pytest.mark.parametrize("sql", [SQL_STAR, SQL_AGG], ids=["rows", "agg"])
def test_padded_execution_equals_unpadded(hospital, hospital_dt, sql):
    plan = _optimize(_query(hospital, hospital_dt, sql), transform="sql")
    ref = execute_plan(plan, hospital.tables).to_numpy()
    n = hospital.n_rows()
    pad = 513  # non-power-of-two padding, pad rows full of zeros
    tables = {t: dict(cols) for t, cols in hospital.tables.items()}
    tables["patients"] = {
        c: np.concatenate([v, np.zeros(pad, v.dtype)])
        for c, v in hospital.tables["patients"].items()
    }
    got = execute_plan(
        plan, tables, row_valid=np.arange(n + pad) < n
    ).to_numpy()
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# PredictionQueryServer
# ---------------------------------------------------------------------------


def _batch(n, seed):
    return make_hospital(n, seed=seed).tables["patients"]


def test_server_matches_execute_plan(hospital, dt_query):
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("risk", dt_query, hospital.tables)
    rows = _batch(300, seed=9)
    got = srv.execute("risk", rows)
    tables = {t: dict(cols) for t, cols in hospital.tables.items()}
    tables["patients"] = rows
    plan = _optimize(dt_query, transform="sql")
    ref = execute_plan(plan, tables).to_numpy()
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, atol=1e-6)


def test_server_zero_recompiles_after_warmup(hospital, dt_query):
    clear_plan_cache()
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("risk", dt_query, hospital.tables)
    srv.execute("risk", _batch(100, seed=3))  # warm the 64..128 bucket
    warm = srv.recompiles()
    assert warm >= 1
    for i, n in enumerate((65, 128, 80, 127)):  # all land in bucket 128
        srv.execute("risk", _batch(n, seed=20 + i))
    assert srv.recompiles() == warm  # zero XLA recompiles after warmup
    assert srv.stats.bucket_misses == 1
    assert srv.stats.bucket_hits == 4
    # a new bucket compiles exactly once, then is hot too
    srv.execute("risk", _batch(200, seed=30))
    grown = srv.recompiles()
    assert grown == warm + 1
    srv.execute("risk", _batch(129, seed=31))
    assert srv.recompiles() == grown


def test_server_shares_optimized_plan_across_registrations(hospital, dt_query):
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    a = srv.register("a", dt_query, hospital.tables)
    b = srv.register("b", dt_query.copy(), hospital.tables)
    assert srv.stats.plan_cache_misses == 1
    assert srv.stats.plan_cache_hits == 1
    assert a.plan is b.plan
    assert a.compiled is b.compiled


def test_server_microbatch_matches_per_request(hospital, dt_query):
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("risk", dt_query, hospital.tables)
    sizes = (50, 40, 30, 60)
    batches = [_batch(n, seed=40 + i) for i, n in enumerate(sizes)]
    reqs = [srv.submit("risk", b) for b in batches]
    srv.flush()
    assert srv.stats.coalesced_requests == len(sizes)
    assert srv.stats.batches_executed == 1  # one padded execution for all
    solo = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    solo.register("risk", dt_query, hospital.tables)
    for req, b in zip(reqs, batches):
        assert req.done
        ref = solo.execute("risk", b)
        for k in ref:
            np.testing.assert_allclose(req.result[k], ref[k], rtol=1e-5, atol=1e-6)


def test_server_aggregate_and_udf_paths(hospital, hospital_dt):
    # aggregates and host-boundary (UDF) plans coalesce via segment ids:
    # one padded execution per flush, split back per request
    agg_q = _query(hospital, hospital_dt, SQL_AGG)
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("agg", agg_q, hospital.tables)
    udf_q = _query(hospital, hospital_dt)
    srv_udf = PredictionQueryServer(options=OptimizerOptions(transform="none"))
    srv_udf.register("udf", udf_q, hospital.tables)

    rows = _batch(200, seed=8)
    tables = {t: dict(cols) for t, cols in hospital.tables.items()}
    tables["patients"] = rows

    agg = srv.execute("agg", rows)
    ref = execute_plan(_optimize(agg_q, transform="sql"), tables).to_numpy()
    for k in ref:
        np.testing.assert_allclose(agg[k], ref[k], rtol=1e-5)

    batch2 = _batch(77, 9)
    r1, r2 = srv_udf.submit("udf", rows), srv_udf.submit("udf", batch2)
    srv_udf.flush()
    assert srv_udf.stats.batches_executed == 1  # coalesced across the boundary
    assert srv_udf.stats.segmented_batches == 1
    assert srv_udf.stats.coalesced_requests == 2
    ref = execute_plan(_optimize(udf_q, transform="none"), tables).to_numpy()
    for k in ref:
        np.testing.assert_allclose(r1.result[k], ref[k], rtol=1e-5, atol=1e-6)
    tables["patients"] = batch2
    ref2 = execute_plan(_optimize(udf_q, transform="none"), tables).to_numpy()
    assert r2.done
    for k in ref2:
        np.testing.assert_allclose(r2.result[k], ref2[k], rtol=1e-5, atol=1e-6)


def test_server_coalesces_aggregates_with_segment_ids(hospital, hospital_dt):
    # two aggregate requests share one segmented execution, each getting its
    # own fold — bitwise-identical to serving them alone
    agg_q = _query(hospital, hospital_dt, SQL_AGG)
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("agg", agg_q, hospital.tables)
    b1, b2 = _batch(150, seed=21), _batch(90, seed=22)
    r1, r2 = srv.submit("agg", b1), srv.submit("agg", b2)
    srv.flush()
    assert srv.stats.batches_executed == 1
    assert srv.stats.segmented_batches == 1
    solo = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    solo.register("agg", agg_q, hospital.tables)
    for req, b in ((r1, b1), (r2, b2)):
        ref = solo.execute("agg", b)
        for k in ref:
            assert req.result[k].shape == ref[k].shape
            np.testing.assert_allclose(req.result[k], ref[k], rtol=1e-4)


def test_server_validates_batch_schema(hospital, dt_query):
    srv = PredictionQueryServer(options=OptimizerOptions(transform="sql"))
    srv.register("risk", dt_query, hospital.tables)
    with pytest.raises(KeyError):
        srv.submit("risk", {"age": np.zeros(4)})
    ragged = dict(_batch(10, seed=2))
    ragged["age"] = ragged["age"][:7]  # mismatched column length
    with pytest.raises(ValueError, match="ragged"):
        srv.submit("risk", ragged)


def test_server_chunks_oversized_batches(hospital, dt_query):
    clear_plan_cache()
    srv = PredictionQueryServer(
        options=OptimizerOptions(transform="sql"), min_bucket=8, max_bucket=64,
    )
    srv.register("risk", dt_query, hospital.tables)
    srv.execute("risk", _batch(64, seed=1))  # warm the max_bucket program
    warm = srv.recompiles()
    rows = _batch(200, seed=7)  # 200 > max_bucket: 64+64+64+8-bucket chunks
    got = srv.execute("risk", rows)
    # chunking keeps every compiled program at or below max_bucket: only the
    # 8-row tail bucket is new; no bucket above 64 was compiled
    # (snapshot before the reference run below, which shares the cached
    # compiled plan and traces once more for its unpadded shape)
    assert srv.recompiles() == warm + 1
    assert all(b <= 64 for _, _, b in srv._seen_buckets)
    tables = {t: dict(cols) for t, cols in hospital.tables.items()}
    tables["patients"] = rows
    ref = execute_plan(_optimize(dt_query, transform="sql"), tables).to_numpy()
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, atol=1e-6)
