"""The persistent plan-artifact store: warm starts across processes.

Covers the two disk tiers (optimizer output per query fingerprint;
AOT-exported stage executables per (stage fingerprint, env digest)), their
failure modes (corruption, version/backend mismatch, concurrent writers,
eviction), and the acceptance path: a query prepared and served in process A
re-prepares in process B with the same ``cache_dir`` and serves its
previously-seen buckets with **zero new XLA traces**, while a fingerprint
mismatch (perturbed model weights) falls back cleanly to live compilation.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

import repro as raven
from repro.data.datasets import make_hospital
from repro.exec.artifact_store import (
    STORE_VERSION,
    ArtifactStore,
    env_digest,
)
from repro.relational.engine import (
    PLAN_CACHE_STATS,
    clear_plan_cache,
    get_artifact_store,
    set_artifact_store,
)

SQL = "SELECT * FROM PREDICT(model='m', data=patients) AS p WHERE score >= :t"
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _isolated_store():
    """Each test starts with an empty in-memory plan cache and no store, and
    never leaks its store into later tests."""
    clear_plan_cache()
    set_artifact_store(None)
    yield
    set_artifact_store(None)
    clear_plan_cache()


def _serve_once(tables, pipe, cache_dir, *, sizes=(100, 200), transform="sql"):
    """connect -> prepare -> serve -> submit one batch per size (flushing
    between, so each size lands its own bucket). Returns (session, scores).

    Drains the store's background export writer before returning so the
    on-disk state is deterministic for the assertions that follow."""
    db = raven.connect(tables, stats="auto", cache_dir=cache_dir)
    db.register_model("m", pipe)
    prep = db.sql(SQL).prepare(transform=transform, params={"t": 0.5})
    prep.serve("hot")
    outs = []
    for i, n in enumerate(sizes):
        req = prep.submit(make_hospital(n, seed=40 + i).tables["patients"])
        db.flush()
        outs.append(np.sort(np.asarray(req.result["score"])))
    db.artifact_store.drain()
    return db, outs


# ---------------------------------------------------------------------------
# store API
# ---------------------------------------------------------------------------


def test_plan_layer_roundtrip(tmp_path, hospital, hospital_gb):
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.5})
    store = ArtifactStore(str(tmp_path))
    assert store.save_plan("qkey", prep.plan, prep.report)
    loaded = store.load_plan("qkey")
    assert loaded is not None
    plan, report = loaded
    from repro.relational.engine import plan_fingerprint

    assert plan_fingerprint(plan) == prep.fingerprint
    assert report.transforms == prep.report.transforms
    assert store.load_plan("missing") is None
    assert store.stats.plan_hits == 1 and store.stats.plan_misses == 1


def test_unstable_plan_content_is_skipped(tmp_path, hospital, hospital_gb):
    """MLtoDNN plans carry live closures: never persisted, never crashing."""
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="dnn", params={"t": 0.5})
    store = ArtifactStore(str(tmp_path))
    assert not store.save_plan("qkey", prep.plan, prep.report)
    assert store.stats.skipped == 1
    assert store.load_plan("qkey") is None


def test_env_digest_keys_structure_not_values():
    a = {"t": {"x": np.zeros(8, np.float32)}}
    b = {"t": {"x": np.ones(8, np.float32)}}
    assert env_digest(a) == env_digest(b)
    wider = {"t": {"x": np.zeros(16, np.float32)}}
    other_dtype = {"t": {"x": np.zeros(8, np.int32)}}
    renamed = {"t": {"y": np.zeros(8, np.float32)}}
    assert len({env_digest(a), env_digest(wider),
                env_digest(other_dtype), env_digest(renamed)}) == 4


# ---------------------------------------------------------------------------
# in-process warm start (fresh compiled-plan cache, shared cache_dir)
# ---------------------------------------------------------------------------


def test_fresh_session_warm_starts_from_disk(tmp_path, hospital, hospital_gb):
    cache = str(tmp_path / "cache")
    _, cold = _serve_once(hospital.tables, hospital_gb, cache)
    cold_stats = PLAN_CACHE_STATS.snapshot()
    assert cold_stats["traces"] >= 2  # one per bucket
    assert cold_stats["disk_misses"] >= 2

    clear_plan_cache()
    set_artifact_store(None)
    db, warm = _serve_once(hospital.tables, hospital_gb, cache)
    stats = db.cache_stats()
    assert stats["traces"] == 0, "warm process must not trace served buckets"
    assert stats["disk_hits"] > 0
    assert stats["server"]["warm_started_buckets"] >= 2
    assert stats["artifact_store"]["plan_hits"] == 1
    for c, w in zip(cold, warm):
        np.testing.assert_allclose(c, w, rtol=1e-6)
    # the stage-level disk loads surface in explain()'s per-stage lines
    assert any(s.disk_loads for s in db.server.queries["hot"].compiled.stages)


def test_unseen_bucket_traces_live_and_persists(tmp_path, hospital, hospital_gb):
    cache = str(tmp_path / "cache")
    _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    clear_plan_cache()
    set_artifact_store(None)
    db, _ = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100, 900))
    stats = db.cache_stats()
    # 100-row bucket came from disk; the never-seen 900-row bucket traced
    assert stats["disk_hits"] > 0
    assert stats["traces"] == 1
    assert stats["artifact_store"]["stage_saves"] == 1


def test_cacheless_connect_clears_the_global_store(tmp_path, hospital):
    db = raven.connect(hospital.tables, stats=None, cache_dir=str(tmp_path))
    assert get_artifact_store() is db.artifact_store
    # a later cache-less session must not inherit (and write into) the
    # previous session's store
    raven.connect(hospital.tables, stats=None)
    assert get_artifact_store() is None


def test_close_uninstalls_own_store(tmp_path, hospital):
    with raven.connect(
        hospital.tables, stats=None, cache_dir=str(tmp_path)
    ) as db:
        assert get_artifact_store() is db.artifact_store
    assert get_artifact_store() is None


def test_identity_hashed_stage_never_touches_the_store(tmp_path, hospital):
    """A TensorOp with a raw closure (no __fingerprint_token__) hashes by
    id(): its fingerprint is meaningless in another process, so neither
    loads nor saves may key on it."""
    import jax.numpy as jnp

    from repro.relational.engine import Scan, TensorOp, compile_plan

    store = ArtifactStore(str(tmp_path))
    set_artifact_store(store)
    plan = TensorOp(
        child=Scan(table="patients", columns=["bmi"]),
        fn=lambda cols: {"double_bmi": cols["bmi"] * 2.0},
        output_names=["double_bmi"],
    )
    compiled = compile_plan(plan)
    assert not compiled.graph.stages[0].content_stable
    db = {"patients": {"bmi": jnp.asarray(np.arange(8.0, dtype=np.float32))}}
    out = compiled(db)
    np.testing.assert_allclose(
        np.asarray(out.columns["double_bmi"]), np.arange(8.0) * 2
    )
    assert compiled.warm_start(store) == 0
    assert store.stats.stage_saves == 0 and store.stats.stage_misses == 0
    assert not os.listdir(os.path.join(store.root, "stages"))


def test_reregistration_does_not_fabricate_disk_hits(tmp_path, hospital, hospital_gb):
    """Buckets traced live (and saved) by THIS process must not be counted
    as disk warm starts when the query is re-registered."""
    cache = str(tmp_path / "cache")
    db = raven.connect(hospital.tables, stats="auto", cache_dir=cache)
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.5})
    prep.serve("hot")
    prep.submit(make_hospital(100, seed=40).tables["patients"])
    db.flush()
    assert db.cache_stats()["disk_hits"] == 0
    prep.serve("hot")  # re-register under the same name
    stats = db.cache_stats()
    assert stats["disk_hits"] == 0
    assert stats["server"]["warm_started_buckets"] == 0


# ---------------------------------------------------------------------------
# failure modes
# ---------------------------------------------------------------------------


def _stage_entry_files(cache: str, name: str) -> list[str]:
    root = os.path.join(cache, "stages")
    return [
        os.path.join(dirpath, name)
        for dirpath, _, files in os.walk(root)
        if name in files
    ]


def test_corrupted_stage_artifact_falls_back_live(tmp_path, hospital, hospital_gb):
    cache = str(tmp_path / "cache")
    _, cold = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    blobs = _stage_entry_files(cache, "exported.bin")
    assert blobs
    for b in blobs:  # truncate + garbage: deserialization must fail
        with open(b, "wb") as f:
            f.write(b"\x00garbage")
    clear_plan_cache()
    set_artifact_store(None)
    db, warm = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    stats = db.cache_stats()
    assert stats["traces"] >= 1  # compiled live, no crash
    assert stats["artifact_store"]["corrupt"] >= 1
    np.testing.assert_allclose(cold[0], warm[0], rtol=1e-6)
    # the quarantined entry was rebuilt by the live compile
    assert get_artifact_store().stats.stage_saves >= 1


def test_corrupted_plan_blob_falls_back_live(tmp_path, hospital, hospital_gb):
    cache = str(tmp_path / "cache")
    _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    plans = _stage_entry_files(cache, "plan.pkl") or [
        os.path.join(cache, "plans", d, "plan.pkl")
        for d in os.listdir(os.path.join(cache, "plans"))
    ]
    assert plans
    for p in plans:
        with open(p, "wb") as f:
            f.write(b"not a pickle")
    clear_plan_cache()
    set_artifact_store(None)
    db, _ = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    assert db.cache_stats()["artifact_store"]["corrupt"] >= 1


def _rewrite_meta(cache: str, mutate) -> int:
    n = 0
    for dirpath, _, files in os.walk(cache):
        if "meta.json" in files:
            p = os.path.join(dirpath, "meta.json")
            with open(p) as f:
                meta = json.load(f)
            mutate(meta)
            with open(p, "w") as f:
                json.dump(meta, f)
            n += 1
    return n


@pytest.mark.parametrize(
    "mutate",
    [
        lambda m: m.update(store_version=STORE_VERSION + 1),
        lambda m: m.update(backend="tpu"),
        lambda m: m.update(jax_version="0.0.1"),
    ],
    ids=["store_version", "backend", "jax_version"],
)
def test_incompatible_artifacts_rejected(tmp_path, hospital, hospital_gb, mutate):
    cache = str(tmp_path / "cache")
    _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    assert _rewrite_meta(cache, mutate) >= 2  # plan + stage entries
    clear_plan_cache()
    set_artifact_store(None)
    db, _ = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    stats = db.cache_stats()
    assert stats["disk_hits"] == 0
    assert stats["traces"] >= 1
    assert stats["artifact_store"]["incompatible"] >= 2


def test_concurrent_writers_do_not_clobber(tmp_path):
    """Racing saves of the same content-addressed key: atomic rename means
    one complete winner, losers discard, and the entry always loads."""
    import jax.numpy as jnp

    store = ArtifactStore(str(tmp_path))

    def fn(env):
        return {"y": env["t"]["x"] * 2.0}

    env = {"t": {"x": jnp.arange(32, dtype=jnp.float32)}}
    digest = env_digest(env)
    errors: list[BaseException] = []

    def writer():
        try:
            store.save_stage("stagefp", digest, fn, env)
        except BaseException as e:  # pragma: no cover - the assertion target
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert store.stage_digests("stagefp") == [digest]
    call = store.load_stage("stagefp", digest)
    assert call is not None
    np.testing.assert_allclose(
        np.asarray(call(env)["y"]), np.arange(32) * 2.0
    )
    # no tmp dirs left behind
    assert not [d for d in os.listdir(store.root) if d.startswith(".art_tmp_")]


def test_eviction_cap_bounds_the_cache_dir(tmp_path, hospital, hospital_gb):
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.5})
    store = ArtifactStore(str(tmp_path), max_entries=3)
    for i in range(8):
        assert store.save_plan(f"q{i}", prep.plan, prep.report)
    assert len(store._entries()) <= 3
    assert store.stats.evictions >= 5
    # evicted entries miss cleanly; survivors still load
    assert store.load_plan("q0") is None
    assert store.load_plan("q7") is not None


def test_size_based_eviction_bounds_total_bytes(tmp_path, hospital, hospital_gb):
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.5})
    probe = ArtifactStore(str(tmp_path / "probe"))
    assert probe.save_plan("probe", prep.plan, prep.report)
    entry_bytes = probe.total_bytes()
    assert entry_bytes > 0
    # cap at ~3 entries' worth of bytes with a generous count cap: the size
    # bound must do the evicting
    store = ArtifactStore(
        str(tmp_path / "cap"), max_entries=1000,
        max_bytes=int(entry_bytes * 3.5),
    )
    for i in range(8):
        assert store.save_plan(f"q{i}", prep.plan, prep.report)
    assert store.total_bytes() <= int(entry_bytes * 3.5)
    assert store.stats.evictions >= 4
    assert store.load_plan("q7") is not None  # newest survives
    assert store.load_plan("q0") is None      # oldest evicted


def test_oversized_single_entry_is_kept_not_thrashed(tmp_path, hospital, hospital_gb):
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_gb)
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.5})
    store = ArtifactStore(str(tmp_path), max_bytes=1)  # everything oversize
    assert store.save_plan("q0", prep.plan, prep.report)
    assert store.save_plan("q1", prep.plan, prep.report)
    # the newest entry always survives (evicting it would thrash forever)
    assert store.load_plan("q1") is not None


def test_background_writer_persists_stage_exports(tmp_path):
    import jax
    import jax.numpy as jnp

    store = ArtifactStore(str(tmp_path))

    def fn(env):
        return {"y": env["t"]["x"] * 3.0}

    env = {"t": {"x": jnp.arange(16, dtype=jnp.float32)}}
    digest = env_digest(env)
    # async save accepts abstract (shape/dtype) envs — the queue never pins
    # device buffers
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), env
    )
    store.save_stage_async("stagefp", digest, fn, abstract)
    store.drain()
    assert store.stats.background_writes == 1
    assert store.stats.stage_saves == 1
    assert store.pending_writes() == 0
    call = store.load_stage("stagefp", digest)
    assert call is not None
    np.testing.assert_allclose(np.asarray(call(env)["y"]), np.arange(16) * 3.0)
    store.drain()  # idempotent


def test_first_compile_export_rides_the_writer_thread(tmp_path, hospital, hospital_gb):
    """Serving a fresh bucket must not pay jax.export inline: the save lands
    via the background writer (visible after drain), keyed identically to a
    synchronous save."""
    cache = str(tmp_path / "cache")
    db, _ = _serve_once(hospital.tables, hospital_gb, cache, sizes=(100,))
    stats = db.cache_stats()["artifact_store"]
    assert stats["background_writes"] >= 1
    assert stats["stage_saves"] >= 1
    assert db.artifact_store.pending_writes() == 0


# ---------------------------------------------------------------------------
# the acceptance path: two real processes
# ---------------------------------------------------------------------------

_CHILD = """
import dataclasses, json, sys
import numpy as np
import repro as raven
from repro.data.datasets import make_hospital
from repro.ml.pipeline import load_pipeline


def perturb_one_weight(pipe):
    # nudge one model weight: every content fingerprint downstream changes
    for n in pipe.nodes:
        for v in n.attrs.values():
            if dataclasses.is_dataclass(v):
                for f in dataclasses.fields(v):
                    arr = getattr(v, f.name)
                    if isinstance(arr, np.ndarray) and arr.dtype.kind == "f":
                        arr += 1e-3
                        return
            elif isinstance(v, np.ndarray) and v.dtype.kind == "f":
                v += 1e-3
                return
    raise RuntimeError("no float weight found to perturb")


def main():
    cache_dir, pipe_path, perturb = sys.argv[1], sys.argv[2], sys.argv[3] == "1"
    pipe = load_pipeline(pipe_path)
    if perturb:
        perturb_one_weight(pipe)
    ds = make_hospital(512, seed=7)
    db = raven.connect(ds.tables, stats="auto", cache_dir=cache_dir)
    db.register_model("m", pipe)
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.5})
    prep.serve("hot")
    sums = []
    for i, n in enumerate((100, 200)):
        req = prep.submit(make_hospital(n, seed=40 + i).tables["patients"])
        db.flush()
        sums.append(float(np.sum(req.result["score"])))
    s = db.cache_stats()
    print(json.dumps({
        "traces": s["traces"],
        "disk_hits": s["disk_hits"],
        "disk_misses": s["disk_misses"],
        "warm_started_buckets": s["server"]["warm_started_buckets"],
        "plan_hits": s["artifact_store"]["plan_hits"],
        "sums": sums,
    }))


main()
"""


def _run_child(script, cache, pipe_path, perturb=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, script, cache, pipe_path, "1" if perturb else "0"],
        capture_output=True, text=True, timeout=300, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_cold_process_warm_start(tmp_path, hospital, hospital_gb):
    """Process A prepares + serves; process B (fresh interpreter, same
    cache_dir) re-prepares with disk hits and zero new XLA traces for the
    buckets A served; a perturbed model misses every key and compiles live."""
    from repro.ml.pipeline import save_pipeline

    script = str(tmp_path / "cold_child.py")
    with open(script, "w") as f:
        f.write(_CHILD)
    pipe_path = str(tmp_path / "pipe.npz")
    save_pipeline(hospital_gb, pipe_path)
    cache = str(tmp_path / "cache")

    a = _run_child(script, cache, pipe_path)
    assert a["traces"] >= 2 and a["disk_hits"] == 0

    b = _run_child(script, cache, pipe_path)
    assert b["disk_hits"] > 0
    assert b["plan_hits"] == 1, "process B must skip re-optimization"
    assert b["warm_started_buckets"] >= 2
    assert b["traces"] == 0, (
        "process B re-traced buckets process A already exported"
    )
    np.testing.assert_allclose(a["sums"], b["sums"], rtol=1e-6)

    c = _run_child(script, cache, pipe_path, perturb=True)
    assert c["disk_hits"] == 0, "changed weights must never reuse artifacts"
    assert c["traces"] >= 2, "mismatch falls back to live compilation"
