"""Relational Pallas kernels vs oracles: the bitwise parity contract.

Extends the kernel-vs-host parity oracle (``kernel_parity`` marker — the CI
kernel-parity job runs exactly these) to the relational kernels:

  * ``gather_join`` (dim-table equi-join gather, upstream filter mask fused)
    and ``segment_agg`` (masked segmented sum/count/min/max) in Pallas
    interpret mode must match their pure-jnp oracles *bit-for-bit* across
    ragged rows, non-multiple-of-block shapes, zero-row inputs,
    all-rows-filtered masks, and single-segment aggregates;
  * at the plan level, ``RAVEN_KERNELS=off`` (the legacy inline-jnp stage
    composition) must be bitwise equal to the kernel path — data is dyadic
    rational (small ints × 0.25) so f32 sums are exact and order-free;
  * the Join stage consumes the stage-build-time baked dim order: the
    entry stage's lowered StableHLO contains no sort when the dimsort env
    entry is present, and the kernel-mode token forks stage and plan
    fingerprints so the two modes never alias compiled artifacts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _bits(a) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(a, np.float32)).view(np.uint32)


def _assert_bitwise(got, want, what: str) -> None:
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, f"{what}: shape {got.shape} != {want.shape}"
    if got.dtype == bool:
        assert np.array_equal(got, want), f"{what}: boolean mismatch"
    else:
        assert np.array_equal(_bits(got), _bits(want)), f"{what}: bit mismatch"


def _dyadic(rng, shape, lo=-40, hi=40):
    return (rng.integers(lo, hi, size=shape) * 0.25).astype(np.float32)


# ---------------------------------------------------------------------------
# gather_join: kernel (interpret) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.kernel_parity
@pytest.mark.parametrize("N", [0, 1, 100, 256, 257])
@pytest.mark.parametrize("M", [1, 7, 128, 130])
def test_gather_join_kernel_bitwise(N, M):
    """Ragged row counts (incl. non-multiples of ``block_n`` and zero rows)
    × dim-table sizes straddling the 128-lane boundary; ~1/3 of fact keys
    miss the dim table — misses must zero their payload and clear ``hit``
    identically in both paths."""
    rng = np.random.default_rng(N * 1000 + M)
    keys = np.sort(rng.choice(3 * M, size=M, replace=False)).astype(np.int32)
    fk = rng.integers(0, 3 * M, size=N).astype(np.int32)  # ~2/3 hit rate
    spay = _dyadic(rng, (M, 3))
    got_out, got_hit = ops.gather_join_op(
        jnp.asarray(fk), jnp.asarray(keys), jnp.asarray(spay), interpret=True
    )
    want_out, want_hit = ref.gather_join_ref(
        jnp.asarray(fk), jnp.asarray(keys), jnp.asarray(spay)
    )
    _assert_bitwise(got_out, want_out, "payload")
    _assert_bitwise(np.asarray(got_hit), np.asarray(want_hit), "hit mask")
    # the hit mask is the ground-truth membership test
    assert np.array_equal(np.asarray(got_hit), np.isin(fk, keys))


@pytest.mark.kernel_parity
def test_gather_join_all_misses_and_all_hits():
    rng = np.random.default_rng(5)
    keys = np.arange(10, dtype=np.int32)
    spay = _dyadic(rng, (10, 2))
    miss = (np.arange(50, dtype=np.int32) + 100)
    out, hit = ops.gather_join_op(
        jnp.asarray(miss), jnp.asarray(keys), jnp.asarray(spay), interpret=True
    )
    assert not np.asarray(hit).any()
    assert not np.asarray(out).any()
    every = np.repeat(keys, 5)
    out2, hit2 = ops.gather_join_op(
        jnp.asarray(every), jnp.asarray(keys), jnp.asarray(spay), interpret=True
    )
    assert np.asarray(hit2).all()
    _assert_bitwise(out2, spay[every], "gathered payload")


# ---------------------------------------------------------------------------
# segment_agg: kernel (interpret) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.kernel_parity
@pytest.mark.parametrize("N", [0, 1, 100, 256, 257])
@pytest.mark.parametrize("S", [1, 4, 5])
def test_segment_agg_kernel_bitwise(N, S):
    """Masked segmented aggregate across ragged rows / non-multiple-of-block
    shapes / a single segment; ~1/3 of rows filtered out via the weight
    column. counts/sums/mins/maxs must all be bit-identical (±inf sentinels
    for empty segments included)."""
    rng = np.random.default_rng(N * 100 + S)
    vals = _dyadic(rng, (N, 3))
    w = (rng.random(N) > 1 / 3).astype(np.float32)
    sid = rng.integers(0, S, size=N).astype(np.int32)
    got = ops.segment_agg_op(
        jnp.asarray(vals), jnp.asarray(w), jnp.asarray(sid),
        num_segments=S, interpret=True,
    )
    want = ref.segment_agg_ref(
        jnp.asarray(vals), jnp.asarray(w), jnp.asarray(sid), num_segments=S
    )
    for g, x, what in zip(got, want, ("counts", "sums", "mins", "maxs")):
        _assert_bitwise(g, x, what)


@pytest.mark.kernel_parity
def test_segment_agg_all_rows_filtered():
    """w == 0 everywhere: zero counts/sums, ±inf extrema — in both paths."""
    rng = np.random.default_rng(9)
    N, S = 130, 3
    vals = _dyadic(rng, (N, 2))
    w = np.zeros(N, np.float32)
    sid = rng.integers(0, S, size=N).astype(np.int32)
    counts, sums, mins, maxs = ops.segment_agg_op(
        jnp.asarray(vals), jnp.asarray(w), jnp.asarray(sid),
        num_segments=S, interpret=True,
    )
    assert not np.asarray(counts).any()
    assert not np.asarray(sums).any()
    assert (np.asarray(mins) == np.inf).all()
    assert (np.asarray(maxs) == -np.inf).all()
    want = ref.segment_agg_ref(
        jnp.asarray(vals), jnp.asarray(w), jnp.asarray(sid), num_segments=S
    )
    for g, x, what in zip((counts, sums, mins, maxs), want,
                          ("counts", "sums", "mins", "maxs")):
        _assert_bitwise(g, x, what)


# ---------------------------------------------------------------------------
# Plan level: RAVEN_KERNELS on/off bit-compat, host oracle, fingerprints
# ---------------------------------------------------------------------------


def _star_tables(n=200, m=16, seed=3):
    """Star schema with dyadic-rational values: f32 sums are exact, so every
    execution path must agree bit-for-bit."""
    rng = np.random.default_rng(seed)
    dim = {
        "k": np.arange(m, dtype=np.int64),
        "v1": _dyadic(rng, m),
        "v2": _dyadic(rng, m),
    }
    fact = {
        # leave some keys unmatched so the join actually filters
        "fk": rng.integers(0, m + 4, size=n).astype(np.int64),
        "x": _dyadic(rng, n),
    }
    return {"f": fact, "d": dim}


def _relational_plan():
    from repro.relational.engine import Aggregate, Filter, Join, Scan
    from repro.relational.expr import Bin, Col, Const

    return Aggregate(
        Filter(
            Join(Scan("f", ["fk", "x"]), "d", "fk", "k", ["v1", "v2"]),
            Bin("gt", Col("x"), Const(0.0)),
        ),
        [
            ("n", "count", "x"), ("sum_x", "sum", "x"),
            ("avg_v1", "mean", "v1"), ("min_v1", "min", "v1"),
            ("max_v2", "max", "v2"),
        ],
    )


def _host_oracle(tables):
    """Careful-f32 numpy reference for the filter→join→group-by plan."""
    f, d = tables["f"], tables["d"]
    pos = np.searchsorted(d["k"], np.clip(f["fk"], d["k"][0], d["k"][-1]))
    pos = np.clip(pos, 0, len(d["k"]) - 1)
    hit = d["k"][pos] == f["fk"]
    mask = hit & (f["x"] > 0)
    x = f["x"][mask].astype(np.float32)
    v1 = d["v1"][pos][mask].astype(np.float32)
    v2 = d["v2"][pos][mask].astype(np.float32)
    n = np.float32(mask.sum())
    out = {
        "n": n,
        "sum_x": np.float32(x.astype(np.float64).sum()),  # dyadic: exact
        "avg_v1": np.float32(v1.astype(np.float64).sum()) / max(n, np.float32(1)),
        "min_v1": v1.min() if len(v1) else np.float32(0),
        "max_v2": v2.max() if len(v2) else np.float32(0),
    }
    return out


def _run_mode(tables, mode, monkeypatch, segments=None):
    from repro.relational.engine import clear_plan_cache, compile_plan

    monkeypatch.setenv("RAVEN_KERNELS", mode)
    clear_plan_cache()
    try:
        cp = compile_plan(_relational_plan(), cache=False)
        db = {t: {c: jnp.asarray(v) for c, v in cols.items()}
              for t, cols in tables.items()}
        res = cp.run(db, segments=segments)
        return {k: np.asarray(v) for k, v in
                res.table.to_numpy(compact=True).items()}
    finally:
        monkeypatch.delenv("RAVEN_KERNELS", raising=False)
        clear_plan_cache()


@pytest.mark.kernel_parity
def test_plan_level_kernels_on_off_bitwise_and_match_host(monkeypatch):
    tables = _star_tables()
    on = _run_mode(tables, "on", monkeypatch)
    off = _run_mode(tables, "off", monkeypatch)
    want = _host_oracle(tables)
    assert set(on) == set(off) == set(want)
    for k in want:
        _assert_bitwise(on[k].reshape(-1), off[k].reshape(-1),
                        f"on-vs-off {k}")
        _assert_bitwise(on[k].reshape(-1)[:1],
                        np.asarray(want[k], np.float32).reshape(-1),
                        f"kernel-vs-host {k}")


@pytest.mark.kernel_parity
def test_plan_level_segmented_on_off_bitwise(monkeypatch):
    """Coalesced serving shape: per-row request-segment ids thread a
    *segmented* aggregate through the kernel — on/off must stay bitwise
    equal per segment."""
    tables = _star_tables(n=150, seed=11)
    rng = np.random.default_rng(2)
    seg = np.sort(rng.integers(0, 6, size=150)).astype(np.int32)
    on = _run_mode(tables, "on", monkeypatch, segments=(seg, 6))
    off = _run_mode(tables, "off", monkeypatch, segments=(seg, 6))
    assert set(on) == set(off)
    for k in on:
        _assert_bitwise(on[k], off[k], f"segmented on-vs-off {k}")


def test_kernel_mode_forks_relational_fingerprints(monkeypatch):
    from repro.relational.engine import Scan, clear_plan_cache, plan_fingerprint

    plan = _relational_plan()
    monkeypatch.setenv("RAVEN_KERNELS", "on")
    clear_plan_cache()
    fp_on = plan_fingerprint(plan)
    monkeypatch.setenv("RAVEN_KERNELS", "off")
    clear_plan_cache()
    fp_off = plan_fingerprint(plan)
    assert fp_on != fp_off
    # plans with no Join/Aggregate must NOT fork on the knob
    scan = Scan("f", ["fk", "x"])
    monkeypatch.setenv("RAVEN_KERNELS", "on")
    s_on = plan_fingerprint(scan)
    monkeypatch.setenv("RAVEN_KERNELS", "off")
    s_off = plan_fingerprint(scan)
    assert s_on == s_off
    monkeypatch.delenv("RAVEN_KERNELS", raising=False)
    clear_plan_cache()


def test_baked_dim_order_eliminates_argsort():
    """Satellite fix: the Join stage must consume the stage-build-time baked
    sort order instead of re-sorting dim keys inside the traced fn — no
    sort op in the entry stage's StableHLO when the dimsort env entry is
    present (and one when it isn't, via the fallback path)."""
    from repro.exec.stages import DIMSORT_KEY, build_stage_graph
    from repro.relational.engine import Join, Scan, dimsort_entry

    tables = _star_tables()
    plan = Join(Scan("f", ["fk", "x"]), "d", "fk", "k", ["v1", "v2"])
    graph = build_stage_graph(plan)
    stage = graph.stages[0]
    env = {t: {c: jnp.asarray(v) for c, v in cols.items()}
           for t, cols in tables.items()}
    with_sorted = jax.jit(stage.fn).lower(
        {**env, DIMSORT_KEY: {"d": dimsort_entry(env["d"]["k"])}}
    ).as_text()
    without = jax.jit(stage.fn).lower(env).as_text()
    assert "stablehlo.sort" not in with_sorted
    assert "stablehlo.sort" in without


def test_dimsort_cache_is_content_keyed():
    """Two distinct jnp arrays with equal content share one cache entry;
    changed content gets a fresh one. Uniqueness marks the kernel-eligible
    entries."""
    from repro.relational.engine import dimsort_entry

    a = dimsort_entry(jnp.asarray(np.array([5, 1, 3], np.int64)))
    b = dimsort_entry(jnp.asarray(np.array([5, 1, 3], np.int64)))
    assert a is b
    c = dimsort_entry(jnp.asarray(np.array([5, 1, 4], np.int64)))
    assert c is not a
    assert "unique" in a
    dup = dimsort_entry(jnp.asarray(np.array([5, 1, 5], np.int64)))
    assert "unique" not in dup
    assert np.array_equal(np.asarray(a["keys"]), [1, 3, 5])
    # stable order: matches jnp.argsort on ties so the fallback gather and
    # the baked gather agree even with duplicate keys
    assert np.array_equal(
        np.asarray(dup["order"]), np.asarray(jnp.argsort(jnp.asarray([5, 1, 5])))
    )
