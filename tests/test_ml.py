"""ML substrate: trainers learn, featurizers invert, pipelines round-trip."""
from __future__ import annotations

import numpy as np
import pytest

from repro.ml import (
    DecisionTreeClassifier,
    GradientBoostingClassifier,
    LogisticRegression,
    OneHotEncoder,
    RandomForestClassifier,
    StandardScaler,
    run_pipeline,
)
from repro.ml.pipeline import load_pipeline, save_pipeline


def _xor_dataset(n=800, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 2))
    y = ((X[:, 0] > 0) ^ (X[:, 1] > 0)).astype(np.int64)
    return X, y


def test_decision_tree_learns_xor():
    X, y = _xor_dataset()
    m = DecisionTreeClassifier(max_depth=4).fit(X, y)
    acc = (m.predict(X) == y).mean()
    assert acc > 0.95  # axis-aligned splits solve XOR exactly by depth 2


def test_gradient_boosting_beats_stump():
    X, y = _xor_dataset(seed=1)
    stump = DecisionTreeClassifier(max_depth=1).fit(X, y)
    gb = GradientBoostingClassifier(n_estimators=20, max_depth=3).fit(X, y)
    acc_s = (stump.predict(X) == y).mean()
    acc_g = (gb.predict(X) == y).mean()
    assert acc_g > 0.9 and acc_g > acc_s


def test_random_forest_majority():
    X, y = _xor_dataset(seed=2)
    rf = RandomForestClassifier(n_estimators=10, max_depth=4).fit(X, y)
    assert (rf.predict(X) == y).mean() > 0.9


def test_logreg_separable_analytic():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(500, 3))
    logit = 2.0 * X[:, 0] - 1.0 * X[:, 2]
    y = (logit > 0).astype(np.int64)
    m = LogisticRegression(n_iter=800, lr=0.5).fit(X, y)
    pred = (1 / (1 + np.exp(-(X @ m.weights + m.bias))) >= 0.5).astype(int)
    assert (pred == y).mean() > 0.97
    # the irrelevant middle feature gets a comparatively tiny weight
    assert abs(m.weights[1]) < 0.25 * abs(m.weights[0])


def test_l1_regularization_creates_zero_weights():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(600, 20))
    y = (X[:, 0] - X[:, 1] > 0).astype(np.int64)  # 18 useless features
    dense = LogisticRegression(alpha=0.0, n_iter=300).fit(X, y)
    sparse = LogisticRegression(alpha=0.05, n_iter=300).fit(X, y)
    assert (sparse.weights == 0).sum() > (dense.weights == 0).sum()
    assert (sparse.weights == 0).sum() >= 10  # paper §2.1: unused features


def test_scaler_onehot_roundtrip():
    rng = np.random.default_rng(5)
    x = rng.normal(3.0, 2.0, size=(256, 4))
    sc = StandardScaler().fit(x)
    z = sc.transform(x)
    np.testing.assert_allclose(z.mean(0), 0.0, atol=1e-9)
    np.testing.assert_allclose(z.std(0), 1.0, atol=1e-6)
    c = rng.integers(0, 5, size=128)
    oh = OneHotEncoder().fit(c)
    M = oh.transform(c)
    assert M.shape == (128, len(np.unique(c)))
    np.testing.assert_array_equal(M.sum(1), 1.0)
    np.testing.assert_array_equal(np.argmax(M, 1), np.searchsorted(oh.categories, c))


def test_pipeline_save_load_roundtrip(tmp_path, hospital, hospital_gb):
    ds = hospital
    path = str(tmp_path / "m.npz")
    save_pipeline(hospital_gb, path)
    loaded = load_pipeline(path)
    joined = ds.joined_columns()
    ins = {k: joined[k] for k in hospital_gb.input_names()}
    a = run_pipeline(hospital_gb, ins)
    b = run_pipeline(loaded, ins)
    np.testing.assert_allclose(a["score"], b["score"], rtol=1e-12)
    np.testing.assert_array_equal(a["label"], b["label"])


@pytest.mark.parametrize("kind", ["dt", "gb", "lr", "rf"])
def test_pipeline_outputs_shape_and_range(hospital, kind):
    from tests.conftest import train_pipeline

    ds = hospital
    pipe = train_pipeline(ds, kind)
    joined = ds.joined_columns()
    out = run_pipeline(pipe, {k: joined[k] for k in pipe.input_names()})
    n = ds.n_rows()
    score = np.asarray(out["score"]).reshape(-1)
    label = np.asarray(out["label"]).reshape(-1)
    assert score.shape == (n,) and label.shape == (n,)
    assert ((score >= 0) & (score <= 1)).all()
    assert set(np.unique(label)) <= {0, 1}
    # trained model must beat chance on its own training data
    assert (label == ds.label).mean() > 0.6
