"""The static-analysis layer: plan verifier, concurrency lint, runtime asserts.

Three families:

  * positive — every lowering path the optimizer emits today verifies clean,
    and a seeded sweep of random valid plans shows verified ⇒ executes;
  * negative — single-field corruptions of valid graphs/sources are rejected
    with the *right* rule id (each registered rule has at least one test
    proving it actually fires);
  * wiring — verify modes thread through connect/prepare/explain without
    touching any fingerprint, and RAVEN_ANALYSIS_ASSERTS arms the serving
    path's invariant checks.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.concurrency import lint_repo, lint_source
from repro.analysis.rules import VerificationWarning, rule_catalog
from repro.analysis.runtime import (
    RuntimeInvariantError,
    asserts_enabled,
    runtime_assert,
)
from repro.analysis.verifier import (
    _EXEC_MEMO,
    check_exec,
    check_graph,
    check_logical,
    enforce,
    resolve_verify_mode,
)
from repro.analysis.__main__ import _scenarios, _toy_pipeline, main as analysis_main
from repro.core.ir import LAggregate, LFilter, LPredict, LScan, PredictionQuery
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.errors import PlanVerificationError
from repro.exec.stages import build_stage_graph
from repro.ml.pipeline import InputSpec, PipelineNode, TrainedPipeline
from repro.relational.engine import MLUdf, compile_plan
from repro.relational.expr import Bin, Col, Const


def rule_ids(violations):
    return {v.rule for v in violations}


def toy_tables(n=32, seed=7):
    rng = np.random.default_rng(seed)
    return {
        "t": {
            "a": rng.normal(size=n),
            "b": rng.normal(size=n),
            "k": rng.integers(0, 8, size=n).astype(np.int32),
        }
    }


def lower(transform, *, with_udf=False, filt=False, agg=False, tables=None):
    """Optimize a toy query down to a StageGraph (verification off)."""
    tables = tables if tables is not None else toy_tables()
    plan = LPredict(
        LScan("t", ["a", "b", "k"]), _toy_pipeline(with_udf), ["score", "label"]
    )
    if filt:
        plan = LFilter(plan, Bin("gt", Col("score"), Const(0.5)))
    if agg:
        plan = LAggregate(
            plan, [("n", "count", ""), ("avg_score", "mean", "score")]
        )
    opts = OptimizerOptions(transform=transform, verify="off")
    physical, _ = RavenOptimizer(options=opts).optimize(PredictionQuery(plan))
    return build_stage_graph(physical), tables


@pytest.fixture(autouse=True)
def _fresh_exec_memo():
    # negative tests mutate graphs in ways the exec memo must not mask
    _EXEC_MEMO.clear()
    yield
    _EXEC_MEMO.clear()


# ---------------------------------------------------------------------------
# Positive: every lowering path verifies clean; verified ⇒ executes
# ---------------------------------------------------------------------------


class TestVerifierClean:
    def test_all_cli_scenarios_verify_clean(self):
        for name, query, opts, tables in _scenarios():
            assert check_logical(query) == [], name
            plan, _ = RavenOptimizer(options=opts).optimize(query)
            graph = build_stage_graph(plan)
            assert check_graph(graph) == [], name
            assert check_exec(graph, tables) == [], name

    @pytest.mark.parametrize("seed", range(8))
    def test_seeded_sweep_verified_implies_executes(self, seed):
        rng = np.random.default_rng(seed)
        transform = ["none", "sql", "dnn"][rng.integers(0, 3)]
        with_udf = bool(rng.integers(0, 2)) and transform == "dnn"
        filt = bool(rng.integers(0, 2))
        agg = bool(rng.integers(0, 2))
        n = int(rng.integers(9, 48))
        tables = toy_tables(n=n, seed=seed)
        graph, tables = lower(
            transform, with_udf=with_udf, filt=filt, agg=agg, tables=tables
        )
        assert check_graph(graph) == []
        assert check_exec(graph, tables) == []
        # verified ⇒ executes: the real engine agrees with the abstraction
        compiled = compile_plan(graph.plan)
        jdb = {
            t: {c: jnp.asarray(v) for c, v in cols.items()}
            for t, cols in tables.items()
        }
        out = compiled(jdb).to_numpy(compact=True)
        assert out, "execution produced no columns"
        for c, v in out.items():
            assert np.all(np.isfinite(np.asarray(v, dtype=np.float64))), c

    def test_split_lowering_has_expected_shape(self):
        graph, _ = lower("dnn", with_udf=True)
        kinds = [s.kind for s in graph.stages]
        assert kinds == ["pure", "host", "pure"]
        assert graph.stages[1].udf.consumes  # block columns are accounted


# ---------------------------------------------------------------------------
# Negative: one corruption, one named rule
# ---------------------------------------------------------------------------


class TestGraphRules:
    def test_graph_shape_rejects_noncontiguous_indices(self):
        graph, _ = lower("dnn")
        graph.stages[0].index = 5
        assert "graph-shape" in rule_ids(check_graph(graph))

    def test_graph_shape_rejects_unknown_kind(self):
        graph, _ = lower("dnn")
        graph.stages[0].kind = "quantum"
        assert "graph-shape" in rule_ids(check_graph(graph))

    def test_schema_chain_rejects_phantom_out_column(self):
        graph, _ = lower("dnn")
        graph.stages[-1].out_columns = graph.stages[-1].out_columns + ("phantom",)
        assert "schema-chain" in rule_ids(check_graph(graph))

    def test_consumes_balance_rejects_dropped_consume(self):
        graph, _ = lower("dnn", with_udf=True)
        host = graph.stages[1]
        host.udf.consumes = ()  # the prefix's __pv_* is now never consumed
        vs = check_graph(graph)
        assert "consumes-balance" in rule_ids(vs)
        msg = "\n".join(str(v) for v in vs)
        assert "__pv_" in msg

    def test_block_leak_rejects_pv_in_output_schema(self):
        graph, _ = lower("dnn", with_udf=True)
        last = graph.stages[-1]
        last.out_columns = last.out_columns + ("__pv_features",)
        assert "block-leak" in rule_ids(check_graph(graph))

    def test_placement_rejects_host_op_in_pure_stage(self):
        graph, _ = lower("dnn")
        udf = MLUdf(None, _toy_pipeline(), ("score", "label"), 64, ())
        graph.stages[0].ops.append(udf)
        assert "placement-pure" in rule_ids(check_graph(graph))

    def test_residual_minimal_rejects_oversized_residual(self):
        graph, _ = lower("dnn", with_udf=True)
        # a residual that is fully tensor-supported should never have been
        # left on the host side of the split
        graph.stages[1].udf.pipeline = _toy_pipeline(with_udf=False)
        assert "residual-minimal" in rule_ids(check_graph(graph))

    def test_fingerprint_stable_rejects_corrupted_chain(self):
        graph, _ = lower("dnn")
        graph.stages[-1].fingerprint = "deadbeef" * 8
        assert "fingerprint-stable" in rule_ids(check_graph(graph))

    def test_fingerprint_stable_rejects_address_bearing_token(self):
        graph, _ = lower("dnn")
        op = graph.stages[0].ops[-1]
        op.fn.__fingerprint_token__ = f"closure at 0x{id(op):x}"
        vs = check_graph(graph)
        assert "fingerprint-stable" in rule_ids(vs)
        assert any("address" in v.message or "0x" in v.message for v in vs)

    def test_fingerprint_deterministic_rejects_unstable_token(self):
        class FlakyFn:
            calls = 0

            @property
            def __fingerprint_token__(self):
                FlakyFn.calls += 1
                return f"tok-{FlakyFn.calls}"

            def __call__(self, cols):
                return cols

        graph, _ = lower("dnn")
        graph.stages[0].ops[-1].fn = FlakyFn()
        assert "fingerprint-deterministic" in rule_ids(check_graph(graph))


class TestExecRules:
    def test_schema_exec_rejects_unknown_column(self):
        graph, tables = lower("dnn")
        del tables["t"]["b"]
        assert "schema-exec" in rule_ids(check_exec(graph, tables))

    def test_schema_exec_rejects_unknown_table(self):
        graph, _ = lower("dnn")
        assert "schema-exec" in rule_ids(check_exec(graph, {}))

    def test_schema_dtype_rejects_bucket_dependent_dtype(self):
        graph, tables = lower("dnn")
        st = graph.stages[0]

        def drifting(env, _orig=st.fn):
            cols, valid, seg = _orig(env)
            if valid.shape[0] == 16:  # static under eval_shape
                cols = {
                    k: (v.astype(jnp.float16) if k == "score" else v)
                    for k, v in cols.items()
                }
            return cols, valid, seg

        st.fn = drifting
        st.fingerprint += ":drifting-dtype"
        assert "schema-dtype" in rule_ids(check_exec(graph, tables))

    def test_bucket_safety_rejects_non_polymorphic_rows(self):
        graph, tables = lower("dnn")
        st = graph.stages[0]

        def padded(env, _orig=st.fn):
            cols, valid, seg = _orig(env)
            cols = dict(cols)
            cols["score"] = jnp.concatenate(
                [cols["score"], jnp.zeros((1,), cols["score"].dtype)]
            )
            return cols, valid, seg

        st.fn = padded
        st.fingerprint += ":padded-rows"
        assert "bucket-safety" in rule_ids(check_exec(graph, tables))

    def test_segment_threading_rejects_dropped_seg(self):
        graph, tables = lower("dnn", agg=True)
        assert graph.needs_segments
        st = graph.stages[-1]

        def dropping(env, _orig=st.fn):
            cols, valid, _seg = _orig(env)
            return cols, valid, None

        st.fn = dropping
        st.fingerprint += ":dropped-seg"
        assert "segment-threading" in rule_ids(check_exec(graph, tables))


class TestLogicalRules:
    def test_pipeline_graph_rejects_duplicate_producer(self):
        pipe = TrainedPipeline(
            inputs=[InputSpec("a", "numeric")],
            outputs=["x"],
            nodes=[
                PipelineNode("concat", ["a"], ["x"], {}),
                PipelineNode("concat", ["a"], ["x"], {}),
            ],
        )
        q = PredictionQuery(LPredict(LScan("t", ["a"]), pipe, ["x"]))
        assert "pipeline-graph" in rule_ids(check_logical(q))

    def test_pipeline_graph_rejects_unproduced_output(self):
        pipe = TrainedPipeline(
            inputs=[InputSpec("a", "numeric")],
            outputs=["ghost"],
            nodes=[PipelineNode("concat", ["a"], ["x"], {})],
        )
        q = PredictionQuery(LPredict(LScan("t", ["a"]), pipe, ["ghost"]))
        assert "pipeline-graph" in rule_ids(check_logical(q))

    def test_logical_schema_rejects_unknown_filter_column(self):
        q = PredictionQuery(
            LFilter(LScan("t", ["a"]), Bin("gt", Col("nope"), Const(0.0)))
        )
        vs = check_logical(q)
        assert "logical-schema" in rule_ids(vs)
        assert any("nope" in v.message for v in vs)


# ---------------------------------------------------------------------------
# Satellite: a corrupted partial-DNN lowering is rejected, rule-named
# ---------------------------------------------------------------------------


class TestCorruptedPartialLowering:
    def test_leaked_block_column_is_rejected_with_rule_id(self):
        graph, _ = lower("dnn", with_udf=True)
        # simulate a buggy split: the suffix forgets to strip its block input
        last = graph.stages[-1]
        last.out_columns = last.out_columns + ("__pv_tweaked",)
        vs = check_graph(graph)
        assert "block-leak" in rule_ids(vs)
        # the diagnostic names the rule — a bare assert would not
        assert any(str(v).startswith("[block-leak]") for v in vs)

    def test_double_consume_is_rejected(self):
        graph, _ = lower("dnn", with_udf=True)
        host = graph.stages[1]
        host.udf.consumes = tuple(host.udf.consumes) * 2
        assert "consumes-balance" in rule_ids(check_graph(graph))


# ---------------------------------------------------------------------------
# Lint rules (synthetic sources) + the repo itself stays clean
# ---------------------------------------------------------------------------


def locked_class(methods: str) -> str:
    """A synthetic threaded class with ``methods`` appended to its body."""
    head = textwrap.dedent(
        """
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition()
                self.x = 0
        """
    )
    return head + textwrap.indent(textwrap.dedent(methods), "    ")


class TestLintRules:
    def test_lock_reentry_fires(self):
        src = locked_class(
            """
            def f(self):
                with self._lock:
                    with self._lock:
                        pass
            """
        )
        assert "lock-reentry" in rule_ids(lint_source(src, "exec/fake.py"))

    def test_condition_is_reentrant_safe(self):
        src = locked_class(
            """
            def f(self):
                with self._cv:
                    with self._cv:
                        pass
            """
        )
        assert "lock-reentry" not in rule_ids(lint_source(src, "exec/fake.py"))

    def test_lock_order_inversion_fires(self):
        src = locked_class(
            """
            def f(self):
                with self._lock:
                    with self._cv:
                        pass

            def g(self):
                with self._cv:
                    with self._lock:
                        pass
            """
        )
        assert "lock-order" in rule_ids(lint_source(src, "exec/fake.py"))

    def test_unlocked_mutation_fires(self):
        src = locked_class(
            """
            def f(self):
                with self._lock:
                    self.x = 1

            def g(self):
                self.x = 2
            """
        )
        vs = lint_source(src, "exec/fake.py")
        assert "unlocked-mutation" in rule_ids(vs)
        assert any("self.x" in v.message or "x" in v.message for v in vs)

    def test_init_is_exempt_and_helpers_inherit_callers_lock(self):
        src = locked_class(
            """
            def f(self):
                with self._lock:
                    self.x = 1
                    self._accrue()

            def _accrue(self):
                self.x += 1
            """
        )
        assert lint_source(src, "exec/fake.py") == []

    def test_pragma_suppresses_one_line(self):
        src = locked_class(
            """
            def f(self):
                with self._lock:
                    with self._lock:  # analysis: allow[lock-reentry]
                        pass
            """
        )
        assert "lock-reentry" not in rule_ids(lint_source(src, "exec/fake.py"))

    def test_fingerprint_hygiene_rejects_id_and_fstrings(self):
        src = textwrap.dedent(
            """
            def make(fn, name):
                fn.__fingerprint_token__ = hex(id(fn))
                fn.__fingerprint_token__ = f"tok-{name}"
                return fn
            """
        )
        vs = lint_source(src, "tensor/fake.py")
        assert "fingerprint-hygiene-src" in rule_ids(vs)
        # both offending assignment lines are flagged (3: hex/id, 4: f-string)
        flagged = {v.where for v in vs if v.rule == "fingerprint-hygiene-src"}
        assert flagged == {"tensor/fake.py:3", "tensor/fake.py:4"}

    def test_fingerprint_hygiene_allows_literal_tokens(self):
        src = 'def make(fn):\n    fn.__fingerprint_token__ = "v1:linear"\n'
        assert lint_source(src, "tensor/fake.py") == []

    def test_host_in_jit_fires(self):
        src = textwrap.dedent(
            """
            import jax
            import numpy as np

            def fn(x):
                return np.sin(x)

            g = jax.jit(fn)
            """
        )
        assert "host-in-jit" in rule_ids(lint_source(src, "exec/fake.py"))

    def test_wallclock_timing_fires_in_runtime_dirs_only(self):
        src = "import time\n\ndef f():\n    return time.time()\n"
        assert "wallclock-timing" in rule_ids(lint_source(src, "exec/fake.py"))
        assert "wallclock-timing" not in rule_ids(
            lint_source(src, "benchmarks/fake.py")
        )

    def test_repo_is_lint_clean(self):
        result = lint_repo()
        assert result.ok, result.describe()

    def test_every_rule_is_registered_once(self):
        ids = [r.id for r in rule_catalog()]
        assert len(ids) == len(set(ids))
        assert len(ids) >= 18


# ---------------------------------------------------------------------------
# Modes: off / warn / strict, env default, session + prepare wiring
# ---------------------------------------------------------------------------


class TestVerifyModes:
    def test_resolve_modes(self, monkeypatch):
        monkeypatch.delenv("RAVEN_VERIFY", raising=False)
        assert resolve_verify_mode(None) == "off"
        assert resolve_verify_mode(True) == "strict"
        assert resolve_verify_mode(False) == "off"
        assert resolve_verify_mode("warn") == "warn"
        monkeypatch.setenv("RAVEN_VERIFY", "strict")
        assert resolve_verify_mode(None) == "strict"
        with pytest.raises(ValueError):
            resolve_verify_mode("loud")

    def test_enforce_strict_raises_with_violations(self):
        graph, _ = lower("dnn", with_udf=True)
        graph.stages[1].udf.consumes = ()
        vs = check_graph(graph)
        with pytest.raises(PlanVerificationError) as ei:
            enforce(vs, "strict", "test")
        assert ei.value.violations == vs
        assert "consumes-balance" in str(ei.value)

    def test_enforce_warn_warns(self):
        graph, _ = lower("dnn", with_udf=True)
        graph.stages[1].udf.consumes = ()
        vs = check_graph(graph)
        with pytest.warns(VerificationWarning):
            lines = enforce(vs, "warn", "test")
        assert lines and any("consumes-balance" in ln for ln in lines)

    def test_enforce_off_and_clean(self):
        assert enforce([], "off", "x") == []
        assert enforce([], "strict", "x") == ["x: ok"]

    def test_strict_session_prepares_and_explains(self):
        import repro as raven

        db = raven.connect(toy_tables(), verify="strict")
        db.register_model("m", _toy_pipeline())
        prep = db.table("t").predict("m").prepare(transform="dnn")
        ex = prep.explain()
        assert "plan verification" in ex
        assert "prepare (stage graph): ok" in ex
        assert "after lowering: ok" in ex
        db.close()

    def test_verify_mode_never_changes_fingerprints(self):
        import repro as raven

        db = raven.connect(toy_tables())
        db.register_model("m", _toy_pipeline())
        fps = {
            db.table("t").predict("m").prepare(transform="sql", verify=v).fingerprint
            for v in (None, True, "warn", "off")
        }
        assert len(fps) == 1
        db.close()

    def test_env_default_applies(self, monkeypatch):
        import repro as raven

        monkeypatch.setenv("RAVEN_VERIFY", "strict")
        db = raven.connect(toy_tables())
        db.register_model("m", _toy_pipeline())
        prep = db.table("t").predict("m").prepare(transform="dnn")
        assert "plan verification" in prep.explain()
        db.close()


# ---------------------------------------------------------------------------
# Satellite: fingerprints are content-addressed across processes
# ---------------------------------------------------------------------------


_FP_SCRIPT = textwrap.dedent(
    """
    import numpy as np
    from repro.analysis.__main__ import _toy_pipeline
    from repro.core.fingerprint import fingerprint
    from repro.core.ir import LPredict, LScan, PredictionQuery
    from repro.core.optimizer import OptimizerOptions, RavenOptimizer
    from repro.exec.stages import build_stage_graph
    from repro.relational.engine import plan_fingerprint

    q = PredictionQuery(
        LPredict(LScan("t", ["a", "b"]), _toy_pipeline(True), ["score", "label"])
    )
    plan, _ = RavenOptimizer(
        options=OptimizerOptions(transform="dnn", verify="off")
    ).optimize(q)
    print(plan_fingerprint(plan))
    for s in build_stage_graph(plan).stages:
        print(s.fingerprint, s.content_stable)
    # dict ordering: rich (dataclass) keys must sort content-stably too
    from repro.analysis.rules import Rule
    d1 = {Rule("b", "s", "x"): 2, Rule("a", "s", "x"): 1, "z": 0, None: 3}
    d2 = {None: 3, "z": 0, Rule("a", "s", "x"): 1, Rule("b", "s", "x"): 2}
    print(fingerprint(d1), fingerprint(d1) == fingerprint(d2))
    """
)


class TestFingerprintStability:
    def test_cross_process_fingerprints_match(self):
        def run(hashseed):
            env = dict(os.environ)
            env["PYTHONHASHSEED"] = hashseed
            env["PYTHONPATH"] = os.pathsep.join(
                p for p in (
                    os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH"),
                ) if p
            )
            out = subprocess.run(
                [sys.executable, "-c", _FP_SCRIPT], env=env,
                capture_output=True, text=True, timeout=300,
            )
            assert out.returncode == 0, out.stderr
            return out.stdout


        a, b = run("0"), run("4242")
        assert a == b
        assert a.strip().endswith("True")  # rich-key dict order is canonical

    def test_dict_key_order_is_canonical_in_process(self):
        from repro.analysis.rules import Rule
        from repro.core.fingerprint import fingerprint

        k1, k2 = Rule("a", "s", "d"), Rule("b", "s", "d")
        assert fingerprint({k1: 1, k2: 2}) == fingerprint({k2: 2, k1: 1})
        # primitive keys keep their historical repr ordering
        assert fingerprint({1: "a", "1": "b"}) == fingerprint({"1": "b", 1: "a"})


# ---------------------------------------------------------------------------
# Satellite: runtime asserts + threaded serving stress under them
# ---------------------------------------------------------------------------


class TestRuntimeAsserts:
    def test_disarmed_by_default(self, monkeypatch):
        monkeypatch.delenv("RAVEN_ANALYSIS_ASSERTS", raising=False)
        assert not asserts_enabled()
        runtime_assert(False, "never raises while disarmed")

    def test_armed_raises(self, monkeypatch):
        monkeypatch.setenv("RAVEN_ANALYSIS_ASSERTS", "1")
        assert asserts_enabled()
        runtime_assert(True, "fine")
        with pytest.raises(RuntimeInvariantError, match="boom"):
            runtime_assert(False, "boom")
        assert issubclass(RuntimeInvariantError, AssertionError)

    def test_threaded_submit_drain_stress(self, monkeypatch):
        import repro as raven

        monkeypatch.setenv("RAVEN_ANALYSIS_ASSERTS", "1")
        db = raven.connect(toy_tables(), verify="strict")
        db.register_model("m", _toy_pipeline())
        prep = db.table("t").predict("m").prepare(transform="dnn")
        prep.serve("stress", max_latency_ms=2.0)

        n_threads, n_submits, rows = 4, 8, 5
        errors: list[BaseException] = []

        def worker(tid):
            rng = np.random.default_rng(tid)
            try:
                for _ in range(n_submits):
                    batch = {
                        "a": rng.normal(size=rows),
                        "b": rng.normal(size=rows),
                        "k": np.zeros(rows, np.int32),
                    }
                    req = prep.submit(batch)
                    out = req.wait(timeout=30.0)
                    assert len(out["score"]) == rows
                    assert np.all(np.isfinite(out["score"]))
            except BaseException as e:  # surfaced to the main thread
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(i,)) for i in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        db.close()
        assert not errors, errors


# ---------------------------------------------------------------------------
# The CLI gate itself
# ---------------------------------------------------------------------------


class TestCli:
    def test_rules_listing(self, capsys):
        assert analysis_main(["--rules"]) == 0
        out = capsys.readouterr().out
        assert "consumes-balance" in out and "lock-order" in out

    def test_full_gate_passes(self, capsys):
        assert analysis_main([]) == 0
        out = capsys.readouterr().out
        assert "lint over" in out
        assert "mltodnn-split" in out
