"""Checkpointing: roundtrip, bf16, retention, async, elastic restore."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    restore_onto_mesh,
    save_checkpoint,
)


def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "params": {
            "w_col": jax.random.normal(k, (8, 16), jnp.float32),
            "embed": jax.random.normal(k, (32, 8), jnp.bfloat16),
        },
        "opt": {
            "m": {"w_col": jnp.zeros((8, 16))},
            "step": jnp.asarray(7, jnp.int32),
        },
    }


def _assert_tree_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )


def test_save_load_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    step, loaded, meta = load_checkpoint(str(tmp_path))
    assert step == 5
    shardings = jax.tree.map(lambda x: None, loaded)
    restored = restore_onto_mesh(loaded, shardings)
    _assert_tree_equal(tree, restored)
    # bf16 leaves restore as bf16
    assert restored["params"]["embed"].dtype == jnp.bfloat16


def test_latest_complete_wins(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, jax.tree.map(lambda x: x + 0 if x.dtype != jnp.int32 else x, t))
    step, _, _ = load_checkpoint(str(tmp_path))
    assert step == 2
    step, _, _ = load_checkpoint(str(tmp_path), step=1)
    assert step == 1


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in range(5):
        mgr.save(s, t)
    mgr.flush()
    import os

    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore a checkpoint onto a (1-device) mesh sharding — the elastic
    path: global arrays -> device_put with target NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = _tree()
    save_checkpoint(str(tmp_path), 0, tree)
    _, loaded, _ = load_checkpoint(str(tmp_path))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {
        "params": {
            "w_col": NamedSharding(mesh, P("data", "model")),
            "embed": NamedSharding(mesh, P("model", None)),
        },
        "opt": {"m": {"w_col": NamedSharding(mesh, P())},
                "step": NamedSharding(mesh, P())},
    }
    restored = restore_onto_mesh(loaded, sh)
    _assert_tree_equal(tree, restored)
    assert restored["params"]["w_col"].sharding.is_equivalent_to(
        sh["params"]["w_col"], 2
    )


def test_atomicity_no_partial_dir_visible(tmp_path):
    """A failed write never leaves a step dir with meta.json missing data."""
    import os

    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    for d in os.listdir(tmp_path):
        assert not d.startswith(".ckpt_tmp_")
        meta = os.path.join(tmp_path, d, "meta.json")
        assert os.path.exists(meta)
