"""StageGraph IR + end-to-end bucketed, coalesced, async serving.

Covers the stage-IR contract (schemas, chained per-stage fingerprints), the
post-UDF bucketing warm path (zero new XLA traces on shape churn, asserted
through ``db.cache_stats()``), pump-driven flushing without ``db.flush()``,
cross-request coalescing under concurrency, typed submit errors, and the
validity-mask property test across host boundaries.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

import repro as raven
from repro.core.ir import TableStats
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.data.datasets import make_hospital
from repro.errors import StaleQueryError, UnknownQueryError
from repro.exec.stages import build_stage_graph, plan_segments, seg_bucket
from repro.relational.engine import (
    MLUdf,
    clear_plan_cache,
    execute_plan,
    walk_plan,
)
from repro.serve import PredictionQueryServer
from repro.sql.parser import parse_prediction_query

SQL_STAR = "SELECT * FROM PREDICT(model='m', data=patients) AS p WHERE score >= 0.6"


def _query(ds, pipe, sql=SQL_STAR):
    stats = {t: TableStats.of(cols) for t, cols in ds.tables.items()}
    return parse_prediction_query(sql, {"m": pipe}, ds.tables, stats=stats)


def _optimize(query, **opts):
    return RavenOptimizer(options=OptimizerOptions(**opts)).optimize(query)


def _batch(n, seed):
    return make_hospital(n, seed=seed).tables["patients"]


@pytest.fixture()
def udf_db(hospital, hospital_dt):
    db = raven.connect(hospital.tables, stats="auto")
    db.register_model("m", hospital_dt)
    yield db
    db.close()


# ---------------------------------------------------------------------------
# The IR itself
# ---------------------------------------------------------------------------


def test_stage_graph_structure_and_schema(hospital, hospital_dt):
    plan, _ = _optimize(_query(hospital, hospital_dt), transform="none")
    graph = build_stage_graph(plan)
    kinds = [s.kind for s in graph.stages]
    assert kinds == ["pure", "host", "pure"]
    scan, udf, post = graph.stages
    assert "patients" in scan.reads
    assert scan.in_columns is None
    assert udf.in_columns == tuple(udf.udf.pipeline.input_names())
    assert "score" in udf.out_columns and "pred" in udf.out_columns
    # the post-UDF filter passes every boundary column through
    assert set(post.out_columns) == set(udf.out_columns)
    assert not graph.is_pure and graph.needs_segments
    assert graph.n_host_boundaries == 1


def test_stage_fingerprints_chain_and_share_prefixes(hospital, hospital_dt):
    star, _ = _optimize(_query(hospital, hospital_dt), transform="none")
    agg, _ = _optimize(
        _query(
            hospital, hospital_dt,
            "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) AS p "
            "WHERE score >= 0.6",
        ),
        transform="none",
    )
    g_star, g_agg = build_stage_graph(star), build_stage_graph(agg)
    # same plan -> identical per-stage fingerprints across graph objects
    again = build_stage_graph(_optimize(_query(hospital, hospital_dt), transform="none")[0])
    assert [s.fingerprint for s in g_star.stages] == [
        s.fingerprint for s in again.stages
    ]
    # different plans sharing a physical prefix share those stage hashes —
    # the property per-stage artifact caching keys on
    assert g_star.stages[0].fingerprint == g_agg.stages[0].fingerprint
    assert g_star.stages[1].fingerprint == g_agg.stages[1].fingerprint
    assert g_star.stages[2].fingerprint != g_agg.stages[2].fingerprint


def test_optimizer_annotates_stage_boundaries(hospital, hospital_dt):
    from repro.exec.stages import describe_segments

    plan, report = _optimize(_query(hospital, hospital_dt), transform="none")
    assert report.stages == describe_segments(plan)
    assert len(report.stages) == len(plan_segments(plan)) == 3
    assert report.stages[0].startswith("pure: Scan[patients]")
    assert report.stages[1].startswith("host: MLUdf")
    assert any("host boundary" in n for n in report.notes)
    _, pure_report = _optimize(_query(hospital, hospital_dt), transform="sql")
    assert len(pure_report.stages) == 1


def test_seg_bucket():
    assert seg_bucket(1) == 4
    assert seg_bucket(4) == 4
    assert seg_bucket(5) == 8
    assert seg_bucket(100) == 128


# ---------------------------------------------------------------------------
# Acceptance: post-UDF bucketing keeps warm requests trace-free
# ---------------------------------------------------------------------------


def test_udf_plan_zero_traces_warm_across_batch_sizes(udf_db):
    clear_plan_cache()
    db = udf_db
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.6"
    ).prepare(transform="none").serve(name="udf")
    assert any(isinstance(p, MLUdf) for p in walk_plan(prep.plan))
    prep.submit(_batch(100, seed=1))
    db.flush()  # warm the 128-row bucket end to end (entry + post-UDF)
    warm = db.cache_stats()
    assert warm["traces"] >= 2  # both pure stages traced at least once
    for i, n in enumerate((65, 128, 80, 127)):  # all land in bucket 128
        req = prep.submit(_batch(n, seed=30 + i))
        db.flush()
        assert req.done
    stats = db.cache_stats()
    assert stats["traces"] == warm["traces"]  # zero new XLA traces, any stage
    assert stats["stage_traces"] == warm["stage_traces"]
    assert stats["server"]["mid_bucket_hits"] >= 4


def test_padded_udf_serving_matches_execute_plan(hospital, hospital_dt, udf_db):
    db = udf_db
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.6"
    ).prepare(transform="none").serve(name="udf")
    rows = _batch(333, seed=5)
    req = prep.submit(rows)
    db.flush()
    tables = {t: dict(cols) for t, cols in hospital.tables.items()}
    tables["patients"] = rows
    plan, _ = _optimize(_query(hospital, hospital_dt), transform="none")
    ref = execute_plan(plan, tables).to_numpy()
    assert set(ref) <= set(req.result)
    for k in ref:
        np.testing.assert_allclose(req.result[k], ref[k], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Acceptance: pump-driven serving, no caller flush
# ---------------------------------------------------------------------------


def test_pump_serves_without_caller_flush(udf_db):
    db = udf_db
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.6"
    ).prepare(transform="none").serve(name="udf", max_latency_ms=10)
    req = prep.submit(_batch(120, seed=3))
    out = req.wait(timeout=30.0)  # no db.flush() anywhere
    assert req.done and len(out["score"]) <= 120
    assert db.server.pump is not None and db.server.pump.flushes >= 1


def test_pump_coalesces_concurrent_submitters(udf_db):
    db = udf_db
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.6"
    ).prepare(transform="none").serve(name="udf", max_latency_ms=150)
    # warm (and drain) so the measured flush starts from a quiet server
    prep.submit(_batch(64, seed=9)).wait(timeout=30.0)
    flushes_before = db.server.stats.flushes
    batches_before = db.server.stats.batches_executed
    batches = [_batch(100, seed=40), _batch(70, seed=41)]
    reqs: list = [None, None]
    barrier = threading.Barrier(2)

    def submitter(i):
        barrier.wait()
        reqs[i] = prep.submit(batches[i])

    threads = [threading.Thread(target=submitter, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    outs = [r.wait(timeout=30.0) for r in reqs]
    # both submits landed inside one latency window: one flush, one
    # coalesced execution, correct per-request row splits
    assert db.server.stats.flushes == flushes_before + 1
    assert db.server.stats.batches_executed == batches_before + 1
    assert db.server.stats.coalesced_requests >= 2
    solo = PredictionQueryServer(options=OptimizerOptions(transform="none"))
    solo.register("udf", db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.6"
    ).ir, db.tables)
    for out, b in zip(outs, batches):
        ref = solo.execute("udf", b)
        for k in ref:
            np.testing.assert_allclose(out[k], ref[k], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Typed submit errors
# ---------------------------------------------------------------------------


def test_submit_unknown_query_raises_typed_error(udf_db):
    with pytest.raises(UnknownQueryError, match="nope"):
        udf_db.server.submit("nope", {"age": np.zeros(4)})
    with pytest.raises(UnknownQueryError):
        udf_db.server.rebind("nope", {"t": 0.5})


def test_submit_stale_fingerprint_raises_typed_error(udf_db):
    db = udf_db
    prep_a = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.6"
    ).prepare(transform="sql").serve(name="risk")
    # re-register a *different* plan under the same serve name
    db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.9"
    ).prepare(transform="sql").serve(name="risk")
    with pytest.raises(StaleQueryError, match="re-registered"):
        prep_a.submit(_batch(32, seed=2))


def test_submit_stale_params_raises_typed_error(udf_db):
    # plan fingerprints are param-invariant by design, so the guard must
    # also catch a re-registration that only changed the bound values
    db = udf_db
    sql = "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    prep_a = db.sql(sql).prepare(
        transform="sql", params={"t": 0.6}
    ).serve(name="risk2")
    db.sql(sql).prepare(transform="sql", params={"t": 0.9}).serve(name="risk2")
    with pytest.raises(StaleQueryError, match="re-registered"):
        prep_a.submit(_batch(32, seed=2))


def test_flush_failure_is_contained_and_pump_survives(udf_db):
    # one bad batch must neither strand its waiters nor kill the pump
    db = udf_db
    sql = "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.6"
    prep = db.sql(sql).prepare(transform="none").serve(
        name="udf", max_latency_ms=10,
    )
    bad = prep.submit(_batch(50, seed=1))
    # poison the enqueued batch past submit-time validation
    bad.columns["age"] = np.array(["x"] * 50, dtype=object)
    with pytest.raises(raven.RavenError, match="failed during execution"):
        bad.wait(timeout=30.0)
    assert bad.error is not None and not bad.done
    assert db.server.pump.running  # the pump thread survived the failure
    ok = prep.submit(_batch(40, seed=2))
    out = ok.wait(timeout=30.0)  # serving continues, no db.flush() anywhere
    assert ok.done and len(out["score"]) <= 40


# ---------------------------------------------------------------------------
# EXPLAIN renders the stage graph
# ---------------------------------------------------------------------------


def test_explain_renders_stage_graph(udf_db):
    prep = udf_db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.6"
    ).prepare(transform="none")
    prep(_batch(64, seed=1))  # give the stages runtimes
    text = prep.explain()
    assert "stage graph" in text
    assert "host" in text and "MLUdf" in text
    for st_ in prep.compiled.stages:
        assert st_.fingerprint[:12] in text  # per-stage fingerprints shown
    assert "avg=" in text and "traces=" in text  # per-stage runtimes
