"""Logical-to-physical transformations (paper §5.1): MLtoSQL / MLtoDNN
equivalence against the interpreted ML runtime, plus fallback semantics."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.rules.ml_to_sql import MLtoSQLUnsupported, compile_pipeline_to_sql
from repro.ml.pipeline import PipelineNode, TrainedPipeline, InputSpec, run_pipeline
from repro.relational.expr import eval_expr
from repro.tensor.compile import compile_pipeline_tensor
from tests.conftest import train_pipeline


@pytest.mark.parametrize("kind", ["dt", "gb", "lr", "rf"])
def test_mltosql_equivalence(hospital, kind):
    pipe = train_pipeline(hospital, kind)
    comp = compile_pipeline_to_sql(pipe)
    joined = hospital.joined_columns()
    env = {k: np.asarray(joined[k], np.float64) for k in pipe.input_names()}
    ref = run_pipeline(pipe, env)
    got_score = np.asarray(eval_expr(comp.exprs["score"], env)).reshape(-1)
    want = np.asarray(ref["score"]).reshape(-1)
    if comp.score_space == "logit":
        got_score = 1.0 / (1.0 + np.exp(-got_score))
    # f32 engine vs f64 runtime: tiny fraction may sit on thresholds
    # (paper §7.4 reports 0.006–0.3% of predictions)
    close = np.isclose(got_score, want, rtol=5e-3, atol=1e-3)
    assert close.mean() > 0.992, f"{1-close.mean():.3%} flipped"
    got_label = np.asarray(eval_expr(comp.exprs["label"], env)).reshape(-1)
    assert (got_label == np.asarray(ref["label"]).reshape(-1)).mean() > 0.992


@pytest.mark.parametrize("kind", ["dt", "gb", "lr", "rf"])
@pytest.mark.parametrize("strategy", ["gemm", "traversal"])
def test_mltodnn_equivalence(hospital, kind, strategy):
    pipe = train_pipeline(hospital, kind)
    if kind == "lr" and strategy == "traversal":
        pytest.skip("tree strategy n/a for linear")
    comp = compile_pipeline_tensor(pipe, strategy=strategy)
    joined = hospital.joined_columns()
    env = {k: np.asarray(joined[k]) for k in pipe.input_names()}
    ref = run_pipeline(pipe, env)
    got = comp.fn({k: np.asarray(v, np.float32) for k, v in env.items()})
    # f32 thresholds flip a tiny fraction of rows onto other leaves — the
    # paper reports 0.006–0.3% (MLtoSQL) / <0.8% (MLtoDNN) in §7.4.
    score_close = np.isclose(
        np.asarray(got["score"]).reshape(-1),
        np.asarray(ref["score"]).reshape(-1),
        rtol=5e-3, atol=1e-3,
    )
    assert score_close.mean() > 0.992, f"{1-score_close.mean():.3%} flipped"
    labels_equal = (
        np.asarray(got["label"]).reshape(-1)
        == np.asarray(ref["label"]).reshape(-1)
    ).mean()
    assert labels_equal > 0.992  # paper §7.4: <0.8% flips allowed


def test_gemm_vs_traversal_agree(hospital):
    pipe = train_pipeline(hospital, "gb")
    joined = hospital.joined_columns()
    env = {k: np.asarray(v, np.float32) for k, v in joined.items()
           if k in pipe.input_names()}
    a = compile_pipeline_tensor(pipe, strategy="gemm").fn(env)
    b = compile_pipeline_tensor(pipe, strategy="traversal").fn(env)
    # both run in f32 over identical featurized inputs -> bitwise-same leaf
    # choices; only the summation order differs
    np.testing.assert_allclose(
        np.asarray(a["score"]).reshape(-1),
        np.asarray(b["score"]).reshape(-1), rtol=1e-4, atol=1e-5,
    )


def _l2_pipeline() -> TrainedPipeline:
    """Pipeline with an l2 normalizer — unsupported by MLtoSQL (needs sqrt
    support declared off per the paper's '4 unsupported operators')."""
    return TrainedPipeline(
        inputs=[InputSpec("a", "numeric"), InputSpec("b", "numeric")],
        outputs=["score", "label"],
        nodes=[
            PipelineNode("concat", ["a", "b"], ["raw"], {}),
            PipelineNode("normalizer", ["raw"], ["norm"], {"norm": "l2"}),
            PipelineNode(
                "linear", ["norm"], ["score", "label"],
                {"weights": np.asarray([1.0, -1.0]), "bias": 0.0,
                 "post": "logistic"},
            ),
        ],
    )


def test_mltosql_whole_pipeline_or_fail():
    with pytest.raises(MLtoSQLUnsupported):
        compile_pipeline_to_sql(_l2_pipeline())


def test_optimizer_falls_back_on_unsupported(hospital):
    """Forcing 'sql' on an unsupported pipeline must fall back to the ML
    runtime, not crash — the paper's whole-pipeline-or-fail semantics."""
    from repro.core.ir import LPredict, LScan, PredictionQuery
    from repro.core.optimizer import OptimizerOptions, RavenOptimizer
    from repro.relational.engine import MLUdf, execute_plan, walk_plan

    pipe = _l2_pipeline()
    rng = np.random.default_rng(0)
    db = {"t": {"a": rng.normal(size=64), "b": rng.normal(size=64)}}
    q = PredictionQuery(
        plan=LPredict(LScan("t", ["a", "b"]), pipe, ["score", "pred"])
    )
    plan, report = RavenOptimizer(
        options=OptimizerOptions(transform="sql")
    ).optimize(q)
    assert any(isinstance(p, MLUdf) for p in walk_plan(plan))
    assert any("fallback" in n for n in report.notes)
    out = execute_plan(plan, db)
    ref = run_pipeline(pipe, db["t"])
    np.testing.assert_allclose(
        np.asarray(out.columns["score"]).reshape(-1),
        np.asarray(ref["score"]).reshape(-1), rtol=1e-5,
    )


def test_mltodnn_covers_normalizer(hospital):
    comp = compile_pipeline_tensor(_l2_pipeline())
    rng = np.random.default_rng(0)
    env = {"a": rng.normal(size=32).astype(np.float32),
           "b": rng.normal(size=32).astype(np.float32)}
    ref = run_pipeline(_l2_pipeline(), env)
    got = comp.fn(env)
    np.testing.assert_allclose(
        np.asarray(got["score"]).reshape(-1),
        np.asarray(ref["score"]).reshape(-1), rtol=1e-5,
    )


def test_prob_space_emission_when_score_visible(hospital):
    """AVG(score) queries must see probability-space scores from MLtoSQL."""
    from repro.core.optimizer import OptimizerOptions, RavenOptimizer
    from repro.relational.engine import execute_plan
    from repro.sql.parser import parse_prediction_query

    pipe = train_pipeline(hospital, "gb")
    sql = "SELECT AVG(score) FROM PREDICT(model='m', data=patients) AS p"
    q = parse_prediction_query(sql, {"m": pipe}, hospital.tables)
    outs = {}
    for t in ("none", "sql", "dnn"):
        plan, _ = RavenOptimizer(
            options=OptimizerOptions(transform=t)
        ).optimize(q)
        outs[t] = float(
            np.asarray(execute_plan(plan, hospital.tables).columns["mean_score"])[0]
        )
    assert abs(outs["sql"] - outs["none"]) < 5e-3
    assert abs(outs["dnn"] - outs["none"]) < 5e-3
