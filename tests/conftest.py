"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
multi-device behaviour is tested via subprocesses (test_distributed.py)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data.datasets import (
    make_credit_card,
    make_expedia,
    make_flights,
    make_hospital,
)
from repro.ml import (
    DecisionTreeClassifier,
    GradientBoostingClassifier,
    LogisticRegression,
    RandomForestClassifier,
    fit_pipeline,
)


@pytest.fixture(scope="session")
def hospital():
    return make_hospital(2048, seed=1)


@pytest.fixture(scope="session")
def credit_card():
    return make_credit_card(1024, seed=0)


@pytest.fixture(scope="session")
def expedia():
    return make_expedia(1024, seed=2)


@pytest.fixture(scope="session")
def flights():
    return make_flights(1024, seed=3)


ESTIMATORS = {
    "dt": lambda: DecisionTreeClassifier(max_depth=6),
    "lr": lambda: LogisticRegression(alpha=0.003, n_iter=120),
    "gb": lambda: GradientBoostingClassifier(n_estimators=8, max_depth=3),
    "rf": lambda: RandomForestClassifier(n_estimators=6, max_depth=5),
}


def train_pipeline(ds, kind: str):
    joined = ds.joined_columns()
    return fit_pipeline(
        joined, ds.label, ds.numeric, ds.categorical,
        ESTIMATORS[kind](), categories=ds.categories(),
    )


@pytest.fixture(scope="session")
def hospital_dt(hospital):
    return train_pipeline(hospital, "dt")


@pytest.fixture(scope="session")
def hospital_gb(hospital):
    return train_pipeline(hospital, "gb")


@pytest.fixture(scope="session")
def hospital_lr(hospital):
    return train_pipeline(hospital, "lr")


def predictions_match(a: np.ndarray, b: np.ndarray, max_frac: float = 0.005):
    """Rounding-tolerant prediction equality: the paper itself reports
    MLtoSQL/MLtoDNN flip 0.006–0.3% of predictions (f32 vs f64 thresholds)."""
    a = np.asarray(a).reshape(-1)
    b = np.asarray(b).reshape(-1)
    assert a.shape == b.shape
    frac = float((a != b).mean()) if a.dtype.kind in "iub" else float(
        (np.abs(a - b) > 1e-4).mean()
    )
    assert frac <= max_frac, f"{frac:.4%} of predictions differ"
