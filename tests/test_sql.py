"""PREDICT-statement SQL frontend."""
from __future__ import annotations

import pytest

from repro.core.ir import LAggregate, LFilter, LJoin, LPredict, walk
from repro.sql.parser import parse_prediction_query
from tests.conftest import train_pipeline


def test_parses_joins_filters_aggregates(expedia):
    pipe = train_pipeline(expedia, "lr")
    sql = (
        "SELECT COUNT(*), AVG(score) FROM PREDICT(model='m', data=searches "
        "JOIN hotels ON hotel_id = hotel_id "
        "JOIN destinations ON dest_id = dest_id) AS p "
        "WHERE s_cat0 = 3 AND score >= 0.8"
    )
    q = parse_prediction_query(sql, {"m": pipe}, expedia.tables)
    kinds = [type(n).__name__ for n in walk(q.plan)]
    assert kinds.count("LJoin") == 2
    assert kinds.count("LFilter") == 2  # one below predict, one above
    assert kinds.count("LPredict") == 1
    assert isinstance(q.plan, LAggregate)
    # input predicate sits below the predict node, score predicate above
    pred = q.predict_nodes()[0]
    below = [n for n in walk(pred.child) if isinstance(n, LFilter)]
    assert len(below) == 1


def test_model_loading_from_path(tmp_path, hospital):
    from repro.ml.pipeline import save_pipeline

    pipe = train_pipeline(hospital, "dt")
    path = str(tmp_path / "model.npz")
    save_pipeline(pipe, path)
    sql = f"SELECT COUNT(*) FROM PREDICT(model='{path}', data=patients) AS p"
    q = parse_prediction_query(sql, {path: path}, hospital.tables)
    assert q.predict_nodes()[0].pipeline.n_ops() == pipe.n_ops()


def test_select_star(hospital):
    pipe = train_pipeline(hospital, "dt")
    sql = "SELECT * FROM PREDICT(model='m', data=patients) AS p"
    q = parse_prediction_query(sql, {"m": pipe}, hospital.tables)
    assert not isinstance(q.plan, LAggregate)


def test_syntax_error_raises(hospital):
    pipe = train_pipeline(hospital, "dt")
    with pytest.raises(SyntaxError):
        parse_prediction_query(
            "SELECT FROM PREDICT(model='m' data=patients)",
            {"m": pipe}, hospital.tables,
        )


def test_ne_operator_parses_both_spellings(hospital):
    from repro.core.ir import LFilter
    from repro.relational.expr import Bin

    pipe = train_pipeline(hospital, "dt")
    for op in ("<>", "!="):
        q = parse_prediction_query(
            f"SELECT * FROM PREDICT(model='m', data=patients) AS p "
            f"WHERE asthma {op} 1",
            {"m": pipe}, hospital.tables,
        )
        f = [n for n in walk(q.plan) if isinstance(n, LFilter)][0]
        assert isinstance(f.expr, Bin) and f.expr.op == "ne"


def test_param_placeholder_parses_to_param_slot(hospital):
    from repro.core.ir import LFilter
    from repro.relational.expr import Bin, Param

    pipe = train_pipeline(hospital, "dt")
    q = parse_prediction_query(
        "SELECT * FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= :thresh",
        {"m": pipe}, hospital.tables,
    )
    f = [n for n in walk(q.plan) if isinstance(n, LFilter)][0]
    assert isinstance(f.expr, Bin) and f.expr.b == Param("thresh")
    assert q.params() == {"thresh"}
