"""Hypothesis property tests on the system's invariants."""
from __future__ import annotations

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.rules.propagation import Interval, prune_tree_ensemble
from repro.distributed.compression import ef_init, ef_int8_compress, ef_int8_decompress
from repro.ml import DecisionTreeClassifier
from repro.relational.expr import Bin, Case, Col, Const, Un, eval_expr


# ---------------------------------------------------------------------------
# expr evaluation == numpy semantics
# ---------------------------------------------------------------------------

_finite = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False,
    width=32,
)


@st.composite
def _exprs(draw, depth=0):
    if depth >= 4 or draw(st.booleans()):
        return draw(
            st.one_of(
                st.builds(Col, st.sampled_from(["x", "y"])),
                st.builds(Const, _finite),
            )
        )
    kind = draw(st.sampled_from(["bin", "case", "un"]))
    if kind == "bin":
        op = draw(st.sampled_from(["add", "sub", "mul", "min", "max"]))
        return Bin(op, draw(_exprs(depth + 1)), draw(_exprs(depth + 1)))
    if kind == "un":
        return Un(draw(st.sampled_from(["neg", "abs", "sigmoid"])), draw(_exprs(depth + 1)))
    cond = Bin(
        draw(st.sampled_from(["le", "lt", "ge", "gt"])),
        draw(_exprs(depth + 1)),
        draw(_exprs(depth + 1)),
    )
    return Case(cond, draw(_exprs(depth + 1)), draw(_exprs(depth + 1)))


def _np_eval(e, env):
    if isinstance(e, Col):
        return env[e.name]
    if isinstance(e, Const):
        return np.float32(e.value)
    if isinstance(e, Un):
        f = {"neg": lambda x: -x, "abs": np.abs,
             "sigmoid": lambda x: 1 / (1 + np.exp(-x.astype(np.float64))).astype(np.float32)}
        return f[e.op](_np_eval(e.a, env))
    if isinstance(e, Case):
        return np.where(
            _np_eval(e.cond, env), _np_eval(e.then, env), _np_eval(e.orelse, env)
        )
    a, b = _np_eval(e.a, env), _np_eval(e.b, env)
    f = {"add": np.add, "sub": np.subtract, "mul": np.multiply,
         "min": np.minimum, "max": np.maximum,
         "le": np.less_equal, "lt": np.less,
         "ge": np.greater_equal, "gt": np.greater}
    return f[e.op](a, b)


@settings(max_examples=40, deadline=None)
@given(e=_exprs(), seed=st.integers(0, 2**31 - 1))
def test_expr_eval_matches_numpy_semantics(e, seed):
    rng = np.random.default_rng(seed)
    env = {
        "x": rng.normal(scale=10, size=16).astype(np.float32),
        "y": rng.normal(scale=10, size=16).astype(np.float32),
    }
    got = np.asarray(eval_expr(e, env), np.float64)
    want = np.asarray(_np_eval(e, env), np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tree pruning: any row inside the interval constraint scores identically
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    lo=st.floats(min_value=-2.0, max_value=1.0, allow_nan=False),
    width=st.floats(min_value=0.1, max_value=2.0, allow_nan=False),
)
def test_interval_pruned_tree_agrees_inside_interval(seed, lo, width):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(400, 4))
    y = ((X[:, 0] + 0.5 * X[:, 1] - X[:, 2]) > 0).astype(np.int64)
    ens = DecisionTreeClassifier(max_depth=6).fit(X, y).ensemble
    hi = lo + width
    ivs = [Interval(lo, hi)] + [Interval()] * 3
    pruned = prune_tree_ensemble(ens, ivs)
    assert pruned.n_nodes <= ens.n_nodes
    # rows whose feature 0 is inside [lo, hi] must score identically
    Xin = X[(X[:, 0] >= lo) & (X[:, 0] <= hi)]
    if len(Xin):
        np.testing.assert_allclose(
            pruned.decision_function(Xin), ens.decision_function(Xin), rtol=0
        )


# ---------------------------------------------------------------------------
# int8 error feedback: cumulative transmitted gradient is unbiased
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(2, 12))
def test_error_feedback_accumulates_unbiased(seed, steps):
    rng = np.random.default_rng(seed)
    g_true = {"w": rng.normal(size=(8, 16)).astype(np.float32)}
    state = ef_init(g_true)
    sent_total = np.zeros_like(g_true["w"])
    for _ in range(steps):
        q, s, state = ef_int8_compress(g_true, state)
        sent_total += np.asarray(ef_int8_decompress(q, s)["w"])
    # EF guarantees: Σ sent = Σ true − residual, residual bounded by one
    # quantization step (scale = amax/127 per row)
    resid = np.asarray(state.residual["w"])
    np.testing.assert_allclose(
        sent_total + resid, steps * g_true["w"], rtol=1e-3, atol=1e-3
    )
    step_bound = np.abs(g_true["w"]).max(axis=1, keepdims=True) / 127.0 + 1e-6
    assert (np.abs(resid) <= step_bound * 1.01).all()


# ---------------------------------------------------------------------------
# checkpoint index math round-trips any split
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 64),
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_checkpoint_shard_windows_roundtrip(n, m, seed):
    from repro.checkpoint.store import _index_key, _parse_index

    shape = (n, m)
    idx = (slice(0, n // 2 or 1), slice(0, m))
    key = _index_key(idx)
    back = _parse_index(key, shape)
    assert back == (slice(0, n // 2 or 1), slice(0, m))
