"""Model registry lifecycle: publish → warm → shadow/split → cutover.

Covers the versioned-routing layer end-to-end — resolution paths, typed
option bundles and their deprecation shims, shadow non-leakage,
deterministic splits, the concurrent-cutover atomicity guarantees (zero
dropped, zero re-traced, bitwise-stable per version), and the artifact
store's operator CLI.
"""
from __future__ import annotations

import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import repro as raven
from repro.data.datasets import make_hospital
from repro.errors import (
    RegistryStateError,
    StaleQueryError,
    UnknownModelError,
    UnknownModelVersionError,
)

SQL = "SELECT * FROM PREDICT(model='risk', data=patients) AS p"


def _batch(n: int, seed: int) -> dict[str, np.ndarray]:
    return make_hospital(n, seed=seed).tables["patients"]


@pytest.fixture()
def db(hospital, hospital_dt):
    sess = raven.connect(hospital.tables, stats="auto")
    sess.models.publish("risk", hospital_dt)
    return sess


def _served(db, params=None):
    prep = db.sql(SQL).prepare(transform="sql", params=params)
    prep.serve("q")
    return prep


def _roundtrip(db, prep, batch):
    req = prep.submit(batch)
    db.flush()
    return req


# -- resolution: the one documented path -------------------------------------

def test_resolve_paths(db, hospital_lr):
    db.models.publish("risk", hospital_lr, warm="off")
    assert db.models.resolve("risk").version == 1          # live default
    assert db.models.resolve("risk@live").version == 1
    assert db.models.resolve("risk@latest").version == 2
    assert db.models.resolve("risk@2").version == 2
    with pytest.raises(UnknownModelError):
        db.models.resolve("nope")
    with pytest.raises(UnknownModelVersionError):
        db.models.resolve("risk@9")
    with pytest.raises(UnknownModelVersionError):
        db.models.resolve("risk@banana")
    with pytest.raises(RegistryStateError):
        db.models.resolve("risk@shadow")  # nothing shadowed yet


def test_first_publish_goes_live(db):
    (v1,) = db.models.versions("risk")
    assert v1.state == "live"
    assert v1.ref == "risk@1"
    assert db.models.resolve("risk") is v1
    assert "risk" in db.models
    assert list(db.models) == ["risk"]
    assert len(db.models) == 1


def test_register_model_alias_and_mapping(hospital, hospital_dt):
    db = raven.connect(hospital.tables, stats="auto")
    pipe = db.register_model("risk", hospital_dt)  # thin alias
    assert pipe is hospital_dt
    assert db.models["risk"] is hospital_dt        # parser's mapping protocol
    prep = db.sql(SQL).prepare(transform="sql")
    out = prep(_batch(64, seed=5))
    assert len(next(iter(out.values()))) == 64


def test_versioned_ref_in_sql(db, hospital_lr):
    db.models.publish("risk", hospital_lr, warm="off")
    q1 = db.sql(SQL).prepare(transform="sql")
    q2 = db.sql(
        "SELECT * FROM PREDICT(model='risk@2', data=patients) AS p"
    ).prepare(transform="sql")
    assert q1.query.fingerprint() != q2.query.fingerprint()
    batch = _batch(128, seed=3)
    s1 = q1(batch)["score"]
    s2 = q2(batch)["score"]
    assert not np.array_equal(s1, s2)  # different model families


# -- typed options + shims ---------------------------------------------------

def test_connect_legacy_kwargs_warn(hospital, tmp_path):
    with pytest.warns(DeprecationWarning, match="ConnectOptions"):
        db = raven.connect(
            hospital.tables, stats="auto", cache_dir=str(tmp_path / "c")
        )
    assert db.connect_options.cache_dir == str(tmp_path / "c")


def test_connect_options_bundle_no_warning(hospital, recwarn):
    opts = raven.ConnectOptions(verify="off")
    db = raven.connect(hospital.tables, stats="auto", options=opts)
    assert db.connect_options.verify == "off"
    assert not [w for w in recwarn if w.category is DeprecationWarning]


def test_connect_conflicting_knob_raises(hospital):
    with pytest.raises(ValueError, match="verify"):
        raven.connect(
            hospital.tables, stats="auto",
            options=raven.ConnectOptions(verify="strict"), verify="off",
        )


def test_serve_legacy_kwargs_warn(db):
    prep = db.sql(SQL).prepare(transform="sql")
    with pytest.warns(DeprecationWarning, match="ServeOptions"):
        prep.serve("q", max_coalesce=512)
    assert prep._serve_options.max_coalesce == 512


def test_serve_options_bundle(db):
    prep = db.sql(SQL).prepare(transform="sql")
    prep.serve("q", options=raven.ServeOptions(max_pending=7))
    assert prep._serve_options.max_pending == 7
    with pytest.raises(ValueError, match="max_pending"):
        prep.serve("q2", options=raven.ServeOptions(max_pending=7),
                   max_pending=9)


def test_options_fingerprints_content_stable():
    a = raven.ConnectOptions(cache_dir="/x", verify="warn")
    b = raven.ConnectOptions(cache_dir="/x", verify="warn")
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != raven.ConnectOptions().fingerprint()
    assert a.content_stable
    s1 = raven.ServeOptions(max_latency_ms=5.0)
    s2 = raven.ServeOptions(max_latency_ms=5.0)
    assert s1.fingerprint() == s2.fingerprint()
    assert s1.fingerprint() != raven.ServeOptions().fingerprint()


def test_explain_renders_resolved_options(db):
    prep = db.sql(SQL).prepare(transform="sql")
    prep.serve("q", options=raven.ServeOptions(max_coalesce=256))
    text = prep.explain()
    assert "resolved options" in text
    assert "ConnectOptions(" in text
    assert "ServeOptions(max_coalesce=256)" in text
    assert "fingerprint=" in text


# -- lifecycle ---------------------------------------------------------------

def test_publish_warm_sync_stages_routes(db, hospital_lr):
    prep = _served(db)
    _roundtrip(db, prep, _batch(96, seed=2)).wait(5)
    v2 = db.models.publish("risk", hospital_lr, warm="sync")
    assert v2.state == "ready"
    assert v2.history == ["published", "warming", "ready"]
    route = db.server.route_snapshot("q")
    assert set(route["versions"]) == {"v1", "v2"}
    assert route["versions"]["v2"]["warmed"]


def test_publish_background_wait_ready(db, hospital_lr):
    prep = _served(db)
    _roundtrip(db, prep, _batch(96, seed=2)).wait(5)
    v2 = db.models.publish("risk", hospital_lr)  # warm="background"
    assert v2.wait_ready(timeout=120.0) is v2
    assert v2.state == "ready"


def test_shadow_never_leaks(db, hospital_lr):
    prep = _served(db)
    batch = _batch(200, seed=4)
    oracle = _roundtrip(db, prep, batch).wait(5)  # v1-only answer

    db.models.publish("risk", hospital_lr, warm="sync")
    db.models.shadow("risk", 2)
    for _ in range(3):
        req = _roundtrip(db, prep, batch)
        out = req.wait(5)
        assert req.served_by == "v1"
        for k in oracle:
            assert np.array_equal(out[k], oracle[k], equal_nan=True)
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:  # mirrors run on the boundary pool
        vs = db.server.route_snapshot("q")["versions"]["v2"]
        if vs["shadow_groups"] >= 3:
            break
        time.sleep(0.01)
    assert vs["shadow_groups"] == 3
    assert vs["shadow_errors"] == 0
    assert vs["shadow_rows"] == 600
    assert vs["groups"] == 0  # shadow traffic never counted as served
    assert db.models.resolve("risk@shadow").version == 2
    db.models.shadow("risk", None)
    assert db.server.route_snapshot("q")["shadow"] is None


def test_split_deterministic_counts(db, hospital_lr):
    prep = _served(db)
    batch = _batch(64, seed=6)
    _roundtrip(db, prep, batch).wait(5)
    db.models.publish("risk", hospital_lr, warm="sync")
    db.models.split("risk", {2: 0.25})
    served = []
    for _ in range(16):
        req = _roundtrip(db, prep, batch)
        req.wait(5)
        served.append(req.served_by)
    assert served.count("v2") == 4  # exactly, not statistically
    assert served.count("v1") == 12
    snap = db.server.route_snapshot("q")
    assert snap["versions"]["v2"]["groups"] == 4
    db.models.split("risk", {})  # clears
    req = _roundtrip(db, prep, batch)
    req.wait(5)
    assert req.served_by == "v1"


def test_split_validation(db, hospital_lr):
    _served(db)
    db.models.publish("risk", hospital_lr, warm="sync")
    with pytest.raises(RegistryStateError):
        db.server.set_split("q", {"v2": 1.5})
    with pytest.raises(RegistryStateError):
        db.server.set_split("q", {"v1": 0.5})  # live can't be a split target
    with pytest.raises(UnknownModelVersionError):
        db.server.set_split("q", {"v9": 0.5})


def test_cutover_swaps_and_handles_survive(db, hospital_lr):
    prep = _served(db)
    batch = _batch(128, seed=8)
    _roundtrip(db, prep, batch).wait(5)
    db.models.publish("risk", hospital_lr, warm="sync")
    v2 = db.models.cutover("risk", 2)
    assert v2.state == "live"
    assert db.models.resolve("risk").version == 2
    assert db.models.versions("risk")[0].state == "ready"
    # the outstanding handle keeps working across the cutover
    req = _roundtrip(db, prep, batch)
    req.wait(5)
    assert req.served_by == "v2"
    with pytest.raises(RegistryStateError, match="already live"):
        db.models.cutover("risk", 2)


def test_cutover_zero_retrace(db, hospital_lr):
    prep = _served(db)
    batch = _batch(128, seed=8)
    _roundtrip(db, prep, batch).wait(5)
    db.models.publish("risk", hospital_lr, warm="sync")
    before = db.server.recompiles()
    db.models.cutover("risk", 2)
    req = _roundtrip(db, prep, batch)
    req.wait(5)
    assert db.server.recompiles() == before  # warm swap: zero new traces
    assert db.server.route_snapshot("q")["last_cutover_deficit"] == 0


def test_cutover_require_warm_refuses_cold(db, hospital_lr):
    prep = _served(db)
    _roundtrip(db, prep, _batch(128, seed=8)).wait(5)
    v2 = db.models.publish("risk", hospital_lr, warm="off")
    db.models._ensure_staged(v2)
    route = db.server.routes["q"]
    route.versions["v2"].warmed_ladder.clear()  # simulate a cold version
    with pytest.raises(RegistryStateError, match="not warm"):
        db.server.cutover("q", "v2", require_warm=True)
    db.server.cutover("q", "v2", require_warm=False)  # forced: recorded
    assert db.server.route_snapshot("q")["last_cutover_deficit"] > 0


def test_retire_guards(db, hospital_lr):
    _served(db)
    db.models.publish("risk", hospital_lr, warm="sync")
    with pytest.raises(RegistryStateError, match="live"):
        db.models.retire("risk", 1)
    db.models.shadow("risk", 2)
    with pytest.raises(RegistryStateError, match="shadow"):
        db.models.retire("risk", 2)
    db.models.shadow("risk", None)
    db.models.cutover("risk", 2)
    db.models.retire("risk", 1)
    assert db.models.versions("risk")[0].state == "retired"
    assert "v1" not in db.server.routes["q"].versions


def test_reregister_still_stales_handles(db):
    prep = _served(db)
    token = prep._serve_token
    prep2 = db.sql(SQL).prepare(transform="sql")
    prep2.serve("q")  # same name, fresh registration: new token
    assert prep2._serve_token != token
    with pytest.raises(StaleQueryError):
        db.server.submit("q", _batch(32, seed=1), expect_token=token)


def test_stage_rejects_schema_outside_fact_table(db):
    """A staged version may read columns the live plan pruned, but never
    columns outside the registered fact schema."""
    _served(db)
    live = db.server.routes["q"].versions["v1"]
    assert set(live.scan_columns) <= set(live.fact_dtypes)
    assert set(live.fact_dtypes) == set(db.tables["patients"])


def test_cache_stats_exposes_models(db):
    snap = db.cache_stats()
    assert snap["models"]["risk"]["live"] == 1
    states = [v["state"] for v in snap["models"]["risk"]["versions"]]
    assert states == ["live"]


def test_registry_check_clean_and_dirty(db, hospital_lr):
    from repro.analysis.registry_check import check_registry

    prep = _served(db)
    _roundtrip(db, prep, _batch(64, seed=2)).wait(5)
    db.models.publish("risk", hospital_lr, warm="sync")
    db.models.cutover("risk", 2)
    assert check_registry(db) == []
    # corrupt the recorded history: the independent audit must notice
    db.models.versions("risk")[0].history.append("published")
    vs = check_registry(db)
    assert any(v.rule == "registry-state" for v in vs)


# -- the atomicity stress (the acceptance bar) -------------------------------

@pytest.mark.slow
def test_concurrent_cutover_stress(db, hospital_lr):
    """4 submitting threads race a publish → warm → cutover: zero dropped
    requests, zero warm re-traces, bitwise-stable results per version."""
    prep = _served(db)
    batch = _batch(256, seed=9)
    _roundtrip(db, prep, batch).wait(5)  # prime the v1 bucket

    v2 = db.models.publish("risk", hospital_lr, warm="sync")
    assert v2.state == "ready"
    traces_before = db.server.recompiles()

    results: list[tuple[str, dict]] = []
    errors: list[BaseException] = []
    lock = threading.Lock()
    stop = threading.Event()

    def worker():
        while not stop.is_set():
            try:
                req = prep.submit(batch)
                db.flush()
                out = req.wait(30)
            except BaseException as e:  # noqa: BLE001 — recorded, asserted
                with lock:
                    errors.append(e)
                return
            with lock:
                results.append((req.served_by, out))

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    # let traffic build, then swap mid-flight
    while True:
        with lock:
            if len(results) >= 8:
                break
    db.models.cutover("risk", 2)
    while True:
        with lock:
            if sum(1 for s, _ in results if s == "v2") >= 8:
                break
    stop.set()
    for t in threads:
        t.join(timeout=60)
    db.flush()  # nothing may be left enqueued

    assert errors == []                      # zero dropped
    assert db.server.recompiles() == traces_before  # zero re-traces
    by_version: dict[str, dict] = {}
    for label, out in results:
        assert label in ("v1", "v2")
        ref = by_version.setdefault(label, out)
        for k in ref:                         # bitwise-stable per version
            assert np.array_equal(ref[k], out[k], equal_nan=True)
    assert set(by_version) == {"v1", "v2"}    # both versions actually served
    scores1 = by_version["v1"]["score"]
    scores2 = by_version["v2"]["score"]
    assert not np.array_equal(scores1, scores2)
    snap = db.server.route_snapshot("q")
    assert snap["cutovers"] == 1
    assert snap["last_cutover_deficit"] == 0
    stats = db.cache_stats()
    assert stats["server"]["requests_served"] == len(results) + 1


# -- artifact-store operator CLI ---------------------------------------------

def _store_with_artifacts(root: str):
    import jax.numpy as jnp

    from repro.exec.artifact_store import ArtifactStore

    store = ArtifactStore(root)

    def fn(env):
        return {"y": env["x"] * 2}

    for i in range(3):
        assert store.save_stage(
            f"fp{i:02d}" + "0" * 28, "d" * 32, fn,
            {"x": jnp.zeros((8 + i,), jnp.float32)},
        )
    return store


def test_store_entries_and_prune(tmp_path):
    store = _store_with_artifacts(str(tmp_path))
    entries = store.entries()
    assert len(entries) == 3
    assert all(e.layer == "stage" and e.compat and e.size_bytes > 0
               for e in entries)
    victims = store.prune(max_age_s=0.0, dry_run=True)
    assert len(victims) == 3
    assert len(store.entries()) == 3        # dry run deleted nothing
    keep = sum(e.size_bytes for e in entries[:1])
    store.prune(max_bytes=keep)
    assert len(store.entries()) == 1        # newest survives a byte prune
    store.prune(max_age_s=0.0)
    assert store.entries() == []


def test_store_cli_inspect_and_prune(tmp_path):
    _store_with_artifacts(str(tmp_path))
    run = lambda *a: subprocess.run(  # noqa: E731
        [sys.executable, "-m", "repro.exec.artifact_store",
         "--root", str(tmp_path), *a],
        capture_output=True, text=True, timeout=300,
    )
    out = run("inspect")
    assert out.returncode == 0
    assert "3 entries" in out.stdout
    out = run("inspect", "--layer", "stage", "--fingerprint", "fp01")
    assert "1 entries" in out.stdout
    out = run("inspect", "--json")
    import json

    rows = json.loads(out.stdout)
    assert {r["key"][:4] for r in rows} == {"fp00", "fp01", "fp02"}
    out = run("prune", "--max-age-s", "0", "--dry-run")
    assert "would delete 3" in out.stdout
    out = run("prune", "--max-age-s", "0")
    assert "deleted 3" in out.stdout
    assert "0 entries" in run("inspect").stdout
    out = run("prune")
    assert out.returncode != 0  # needs a bound
