"""The CI definition is part of the contract: it must stay parseable and the
tier-1 job must invoke the canonical gate script (``tests/run_tier1.sh``) —
not an ad-hoc pytest line that could drift from what contributors run."""
from __future__ import annotations

import os

import pytest

yaml = pytest.importorskip("yaml")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")


@pytest.fixture(scope="module")
def workflow():
    with open(WORKFLOW) as f:
        doc = yaml.safe_load(f)
    assert isinstance(doc, dict)
    return doc


def _run_steps(job: dict) -> list[str]:
    return [s["run"].strip() for s in job["steps"] if "run" in s]


def test_workflow_parses_with_expected_jobs(workflow):
    assert {"tier1", "lint", "nightly"} <= set(workflow["jobs"])
    # "on" parses as boolean True in YAML 1.1
    triggers = workflow.get("on", workflow.get(True))
    assert "pull_request" in triggers and "push" in triggers
    assert "schedule" in triggers, "nightly needs a schedule trigger"


def test_tier1_invokes_the_gate_script_exactly(workflow):
    steps = _run_steps(workflow["jobs"]["tier1"])
    assert "tests/run_tier1.sh" in steps, (
        "the tier-1 job must run tests/run_tier1.sh itself, not an ad-hoc "
        f"pytest invocation — got {steps}"
    )
    assert not any("pytest" in s for s in steps)


def test_tier1_installs_pinned_requirements_with_pip_cache(workflow):
    job = workflow["jobs"]["tier1"]
    assert any("-r requirements.txt" in s for s in _run_steps(job))
    setup = next(
        s for s in job["steps"]
        if "actions/setup-python" in s.get("uses", "")
    )
    assert setup["with"]["cache"] == "pip"


def test_concurrency_cancels_superseded_runs(workflow):
    assert workflow["concurrency"]["cancel-in-progress"] is True


def test_lint_job_runs_ruff(workflow):
    steps = _run_steps(workflow["jobs"]["lint"])
    assert any(s.startswith("ruff check") for s in steps)


def test_nightly_runs_full_suite_and_benchmark_smoke(workflow):
    job = workflow["jobs"]["nightly"]
    assert job["if"] == "github.event_name == 'schedule'"
    steps = _run_steps(job)
    # full suite: no `-m "not slow"` filter
    assert any("pytest" in s and "not slow" not in s for s in steps)
    assert any("benchmarks/serve_query.py --smoke" in s for s in steps)


def test_requirements_are_fully_pinned():
    with open(os.path.join(REPO, "requirements.txt")) as f:
        lines = [
            ln.strip() for ln in f
            if ln.strip() and not ln.strip().startswith("#")
        ]
    assert lines, "requirements.txt must pin the baseline environment"
    for ln in lines:
        assert "==" in ln, f"unpinned requirement: {ln!r}"
    names = {ln.split("==")[0].lower() for ln in lines}
    assert {"jax", "jaxlib", "numpy", "pytest", "hypothesis", "ruff"} <= names
