"""The CI definition is part of the contract: it must stay parseable and the
tier-1 job must invoke the canonical gate script (``tests/run_tier1.sh``) —
not an ad-hoc pytest line that could drift from what contributors run."""
from __future__ import annotations

import os
import subprocess

import pytest

yaml = pytest.importorskip("yaml")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")


@pytest.fixture(scope="module")
def workflow():
    with open(WORKFLOW) as f:
        doc = yaml.safe_load(f)
    assert isinstance(doc, dict)
    return doc


def _run_steps(job: dict) -> list[str]:
    return [s["run"].strip() for s in job["steps"] if "run" in s]


def test_workflow_parses_with_expected_jobs(workflow):
    assert {"tier1", "lint", "analysis", "nightly"} <= set(workflow["jobs"])
    # "on" parses as boolean True in YAML 1.1
    triggers = workflow.get("on", workflow.get(True))
    assert "pull_request" in triggers and "push" in triggers
    assert "schedule" in triggers, "nightly needs a schedule trigger"


def test_tier1_invokes_the_gate_script_exactly(workflow):
    steps = _run_steps(workflow["jobs"]["tier1"])
    assert "tests/run_tier1.sh" in steps, (
        "the tier-1 job must run tests/run_tier1.sh itself, not an ad-hoc "
        f"pytest invocation — got {steps}"
    )
    assert not any("pytest" in s for s in steps)


def test_tier1_installs_pinned_requirements_with_pip_cache(workflow):
    job = workflow["jobs"]["tier1"]
    assert any("-r requirements.txt" in s for s in _run_steps(job))
    setup = next(
        s for s in job["steps"]
        if "actions/setup-python" in s.get("uses", "")
    )
    assert setup["with"]["cache"] == "pip"


def test_concurrency_cancels_superseded_runs(workflow):
    assert workflow["concurrency"]["cancel-in-progress"] is True


def test_lint_job_runs_ruff(workflow):
    steps = _run_steps(workflow["jobs"]["lint"])
    assert any(s.startswith("ruff check") for s in steps)


def test_analysis_job_is_the_blocking_static_gate(workflow):
    job = workflow["jobs"]["analysis"]
    # blocking on PRs/pushes (no continue-on-error), skipped only on the
    # nightly schedule like the other PR-gate jobs
    assert job["if"] == "github.event_name != 'schedule'"
    step = next(
        s for s in job["steps"]
        if "python -m repro.analysis" in s.get("run", "")
    )
    assert not step.get("continue-on-error", False)
    assert step["env"]["PYTHONPATH"] == "src"


def test_nightly_runs_full_suite_and_benchmark_smoke(workflow):
    job = workflow["jobs"]["nightly"]
    assert job["if"] == "github.event_name == 'schedule'"
    steps = _run_steps(job)
    # full suite: no `-m "not slow"` filter
    assert any("pytest" in s and "not slow" not in s for s in steps)
    assert any("benchmarks/serve_query.py --smoke" in s for s in steps)


def test_nightly_uploads_benchmark_baseline(workflow):
    job = workflow["jobs"]["nightly"]
    assert any("--json" in s for s in _run_steps(job)), (
        "nightly must write the serving benchmark JSON"
    )
    uploads = [
        s for s in job["steps"] if "upload-artifact" in s.get("uses", "")
    ]
    assert uploads, "nightly must upload the benchmark JSON as an artifact"
    assert uploads[0]["with"]["path"] == "bench_current.json"


def test_nightly_runs_bench_regression_guard(workflow):
    """The fresh smoke numbers must be compared against the *committed*
    baseline — never written over it, so a regressed nightly can't
    self-bless."""
    steps = _run_steps(workflow["jobs"]["nightly"])
    guard = [s for s in steps if "benchmarks/check_regression.py" in s]
    assert guard, "nightly must run the bench regression guard"
    assert "bench_current.json" in guard[0]
    assert "BENCH_serving.json" in guard[0]
    # the smoke run writes to the scratch path, not the committed baseline
    smoke = next(s for s in steps if "--smoke" in s)
    assert "BENCH_serving.json" not in smoke


def test_benchmark_baseline_is_committed():
    """The first perf baseline rides in the repo so regressions have a
    reference point; nightly CI refreshes it as an artifact."""
    path = os.path.join(REPO, "BENCH_serving.json")
    assert os.path.exists(path), "commit BENCH_serving.json (serve_query --json)"
    import json

    with open(path) as f:
        rows = json.load(f)
    for key in ("speedup_served", "cold_warm_traces",
                "mixed_speedup_pipelined", "mixed_parallel_efficiency"):
        assert key in rows, f"baseline missing {key}"


def test_lint_job_guards_against_tracked_bytecode(workflow):
    # the repo once carried 117 committed .pyc files; the guard step keeps
    # them from coming back
    steps = _run_steps(workflow["jobs"]["lint"])
    assert any("__pycache__" in s and "git ls-files" in s for s in steps)


def test_no_tracked_bytecode_or_caches():
    """Mirror of the CI guard, runnable locally: tracked files must never
    include bytecode, __pycache__ dirs, or build artifacts."""
    try:
        out = subprocess.run(
            ["git", "ls-files"], capture_output=True, text=True,
            timeout=60, cwd=REPO,
        )
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    bad = [
        ln for ln in out.stdout.splitlines()
        if "__pycache__/" in ln or ln.endswith((".pyc", ".pyo", ".pyd"))
        or ".egg-info" in ln
    ]
    assert not bad, f"tracked bytecode/build artifacts: {bad[:10]}"


def test_gitignore_covers_bytecode_and_caches():
    with open(os.path.join(REPO, ".gitignore")) as f:
        text = f.read()
    for pat in ("__pycache__/", "*.py[cod]", ".pytest_cache/"):
        assert pat in text, f".gitignore must cover {pat!r}"


def test_requirements_are_fully_pinned():
    with open(os.path.join(REPO, "requirements.txt")) as f:
        lines = [
            ln.strip() for ln in f
            if ln.strip() and not ln.strip().startswith("#")
        ]
    assert lines, "requirements.txt must pin the baseline environment"
    for ln in lines:
        assert "==" in ln, f"unpinned requirement: {ln!r}"
    names = {ln.split("==")[0].lower() for ln in lines}
    assert {"jax", "jaxlib", "numpy", "pytest", "hypothesis", "ruff"} <= names
