"""Partial MLtoDNN: pipeline-splitting lowering.

Property: executing the split — compiled tensor prefix, host residual,
compiled tensor suffix — matches host ``run_pipeline`` *bit-for-bit* on CPU
for elementwise-safe ops (scaler/concat/feature_extractor + a python_udf
residual), across every split shape: residual in the middle, residual first
(suffix-only), residual last (prefix-only), and no residual at all (the
fully-supported degenerate split). Plus: the end-to-end optimizer emits
``TensorOp → MLUdf → TensorOp`` instead of one monolithic MLUdf, cut
columns never leak into query output, ``explain()`` renders the placement,
and a split plan warm-starts with zero re-traces through the artifact store.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rules.ml_to_dnn import (
    MLtoDNNUnsupported,
    compile_pipeline_to_dnn_partial,
)
from repro.ml.pipeline import (
    InputSpec,
    PipelineNode,
    TrainedPipeline,
    run_pipeline,
    split_pipeline,
)
from repro.tensor.compile import tensor_supported

try:  # the property test is hypothesis-driven when available ...
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # ... and a seeded deterministic sweep otherwise
    HAVE_HYPOTHESIS = False


def _udf(X):
    # deterministic, elementwise, f32-exact on both runtimes
    return (X.astype(np.float32) * np.float32(0.5)) + np.float32(0.25)


_udf.__fingerprint_token__ = "test-split-udf-v1"


def _build(k: int, offsets, scales, udf_pos: str) -> TrainedPipeline:
    """k numeric inputs -> concat -> scaler -> feature_extractor, with a
    python_udf inserted at ``udf_pos`` in {none, start, middle, end}."""
    xs = [f"x{i}" for i in range(k)]
    nodes: list[PipelineNode] = []
    off = np.asarray(offsets, dtype=np.float32)
    sc = np.asarray(scales, dtype=np.float32)
    idx = list(reversed(range(k)))

    if udf_pos == "start":
        # unsupported node first: no supported prefix exists (suffix-only)
        nodes.append(
            PipelineNode("python_udf", [xs[0]], ["h0"], {"fn": _udf})
        )
        concat_in = ["h0", *xs[1:]]
    else:
        concat_in = list(xs)
    nodes.append(PipelineNode("concat", concat_in, ["raw"]))
    if udf_pos == "middle":
        nodes.append(PipelineNode("python_udf", ["raw"], ["raw_h"], {"fn": _udf}))
        scaler_in = "raw_h"
    else:
        scaler_in = "raw"
    nodes.append(
        PipelineNode("scaler", [scaler_in], ["scaled"], {"offset": off, "scale": sc})
    )
    nodes.append(
        PipelineNode("feature_extractor", ["scaled"], ["feat"], {"indices": idx})
    )
    final = "feat"
    if udf_pos == "end":
        nodes.append(PipelineNode("python_udf", ["feat"], ["feat_h"], {"fn": _udf}))
        final = "feat_h"
    return TrainedPipeline(
        inputs=[InputSpec(x, "numeric") for x in xs],
        outputs=[final],
        nodes=nodes,
    )


def _run_split(pipe: TrainedPipeline, inputs: dict[str, np.ndarray]):
    """Execute prefix (tensor) -> residual (host) -> suffix (tensor),
    chaining through cut columns exactly as the plan does."""
    part = compile_pipeline_to_dnn_partial(pipe)
    cols: dict[str, np.ndarray] = dict(inputs)

    def tensor_seg(compiled):
        comp, seg = compiled
        out = comp.fn({n: jnp.asarray(cols[n]) for n in comp.input_names})
        for val, col in zip(seg.pipeline.outputs, seg.out_cols):
            cols[col] = np.asarray(out[val])

    if part.full is not None:
        out = part.full.fn({n: jnp.asarray(cols[n]) for n in part.full.input_names})
        return {o: np.asarray(out[o]) for o in pipe.outputs}, part
    if part.prefix is not None:
        tensor_seg(part.prefix)
    if part.residual is not None:
        seg = part.residual
        res = run_pipeline(
            seg.pipeline, {s.name: cols[s.name] for s in seg.pipeline.inputs}
        )
        for val, col in zip(seg.pipeline.outputs, seg.out_cols):
            cols[col] = res[val]
    if part.suffix is not None:
        tensor_seg(part.suffix)
    return {o: cols[o] for o in pipe.outputs}, part


def _check_split_matches_host(k, n, udf_pos, offsets, scales, arr):
    pipe = _build(k, offsets, scales, udf_pos)
    inputs = {f"x{i}": arr[:, i] for i in range(k)}

    host = run_pipeline(pipe, inputs)
    got, part = _run_split(pipe, inputs)

    # split shape is exactly what udf_pos dictates
    if udf_pos == "none":
        assert part.full is not None
    else:
        assert part.residual is not None
        assert (part.prefix is None) == (udf_pos == "start")
        assert (part.suffix is None) == (udf_pos == "end")

    def _2d(x):
        x = np.asarray(x, dtype=np.float32)
        return x.reshape(x.shape[0], 1) if x.ndim == 1 else x

    for o in pipe.outputs:
        want = _2d(host[o])
        have = _2d(got[o])
        assert want.shape == have.shape
        # bit-for-bit: elementwise f32 math must agree exactly on CPU
        assert np.array_equal(
            want.view(np.uint32), have.view(np.uint32)
        ), f"bitwise mismatch on {o}"


if HAVE_HYPOTHESIS:
    finite_f32 = st.floats(
        min_value=-1e3, max_value=1e3, allow_nan=False, width=32
    )

    @settings(max_examples=25, deadline=None)
    @given(
        data=st.data(),
        k=st.integers(min_value=1, max_value=4),
        n=st.integers(min_value=0, max_value=37),
        udf_pos=st.sampled_from(["none", "start", "middle", "end"]),
    )
    def test_split_execution_matches_host_bitwise(data, k, n, udf_pos):
        offsets = data.draw(st.lists(finite_f32, min_size=k, max_size=k))
        scales = data.draw(st.lists(finite_f32, min_size=k, max_size=k))
        rows = data.draw(
            st.lists(
                st.lists(finite_f32, min_size=k, max_size=k),
                min_size=n,
                max_size=n,
            )
        )
        arr = np.asarray(rows, dtype=np.float32).reshape(n, k)
        _check_split_matches_host(k, n, udf_pos, offsets, scales, arr)

else:

    @pytest.mark.parametrize("udf_pos", ["none", "start", "middle", "end"])
    @pytest.mark.parametrize("k,n", [(1, 0), (1, 7), (3, 37), (4, 128)])
    def test_split_execution_matches_host_bitwise(k, n, udf_pos):
        rng = np.random.default_rng(hash((k, n, udf_pos)) % (2**32))
        offsets = rng.uniform(-1e3, 1e3, size=k).astype(np.float32)
        scales = rng.uniform(-1e3, 1e3, size=k).astype(np.float32)
        arr = rng.uniform(-1e3, 1e3, size=(n, k)).astype(np.float32)
        _check_split_matches_host(k, n, udf_pos, offsets, scales, arr)


def test_split_placement_covers_every_node():
    pipe = _build(3, [0.0, 1.0, 2.0], [1.0, 0.5, 2.0], "middle")
    split = split_pipeline(pipe, tensor_supported)
    assert [seg for _, seg in split.placement] == [
        "prefix", "residual", "suffix", "suffix"
    ]
    # every node appears exactly once, in topo order
    assert [lbl.split("[")[0] for lbl, _ in split.placement] == [
        "concat", "python_udf", "scaler", "feature_extractor"
    ]


def test_nothing_lowerable_raises_and_optimizer_falls_back():
    pipe = TrainedPipeline(
        inputs=[InputSpec("x0", "numeric")],
        outputs=["h"],
        nodes=[PipelineNode("python_udf", ["x0"], ["h"], {"fn": _udf})],
    )
    with pytest.raises(MLtoDNNUnsupported):
        compile_pipeline_to_dnn_partial(pipe)


# ---------------------------------------------------------------------------
# End-to-end: optimizer emits the split plan; serving warm-starts it
# ---------------------------------------------------------------------------


def _hospital_split_pipeline(hospital, train_pipeline_fn):
    pipe = train_pipeline_fn(hospital, "gb")
    nodes = list(pipe.nodes)
    mi = next(
        i for i, nd in enumerate(nodes) if nd.op in ("tree_ensemble", "linear")
    )
    udf = PipelineNode(
        "python_udf", [nodes[mi].inputs[0]], ["features_h"], {"fn": _udf}
    )
    model = dataclasses.replace(
        nodes[mi], inputs=["features_h", *nodes[mi].inputs[1:]]
    )
    return dataclasses.replace(
        pipe, nodes=[*nodes[:mi], udf, model, *nodes[mi + 1:]]
    )


@pytest.fixture()
def split_db(hospital):
    import repro as raven
    from tests.conftest import train_pipeline

    joined = hospital.joined_columns()
    db = raven.connect({"patients": joined})
    db.register_model("risk", _hospital_split_pipeline(hospital, train_pipeline))
    yield db, joined
    db.close()


def test_optimizer_emits_split_not_monolithic_udf(split_db):
    from repro.relational.engine import MLUdf, TensorOp, walk_plan

    db, joined = split_db
    prep = db.table("patients").predict("risk").prepare(transform="dnn")
    kinds = [
        type(s).__name__
        for s in walk_plan(prep.plan)
        if isinstance(s, (MLUdf, TensorOp))
    ]
    # innermost-first: prefix TensorOp, host residual, suffix TensorOp
    assert kinds == ["TensorOp", "MLUdf", "TensorOp"]
    udf = next(s for s in walk_plan(prep.plan) if isinstance(s, MLUdf))
    assert len(udf.pipeline.nodes) == 1  # minimal residual
    assert [s.kind for s in prep.compiled.graph.stages] == ["pure", "host", "pure"]

    # results equal the host path; cut columns never reach the output
    pipe = db.models["risk"]
    host = run_pipeline(pipe, {s.name: joined[s.name] for s in pipe.inputs})
    out = prep({k: joined[k] for k in joined})
    assert not [c for c in out if c.startswith("__pv_")]
    assert np.allclose(out["score"], host["score"], rtol=5e-3, atol=1e-5)

    text = prep.explain()
    assert "split across runtimes" in text
    assert "host/residual" in text and "tensor/prefix" in text
    assert "MLtoDNN split" in text


def test_split_plan_zero_warm_retraces(split_db, tmp_path):
    import repro as raven
    from repro.relational.engine import clear_plan_cache, set_artifact_store

    db, joined = split_db
    hospital_pipe = db.models["risk"]
    cache = str(tmp_path / "cache")

    def prepare_and_serve():
        d = raven.connect({"patients": joined}, cache_dir=cache)
        d.register_model("risk", hospital_pipe)
        p = d.table("patients").predict("risk").prepare(transform="dnn")
        p.serve("q")
        r = p.submit({k: joined[k][:200] for k in joined})
        d.flush()
        r.wait()
        d.artifact_store.drain()
        stats = d.cache_stats()
        d.close()
        return stats, np.sort(np.asarray(r.result["score"]))

    clear_plan_cache()
    set_artifact_store(None)
    cold, cold_scores = prepare_and_serve()
    assert cold["traces"] > 0
    # simulate a fresh process: drop the in-memory tier, keep the disk tier
    clear_plan_cache()
    set_artifact_store(None)
    warm, warm_scores = prepare_and_serve()
    # warm start re-traces nothing: every pure stage program — including the
    # split's prefix/suffix TensorOp stages — loads from disk
    assert warm["traces"] == 0, (cold, warm)
    assert warm["disk_hits"] > 0
    np.testing.assert_allclose(cold_scores, warm_scores, rtol=1e-6)
    clear_plan_cache()
    set_artifact_store(None)
