"""Columnar engine vs numpy oracle: scans, FK joins, filters, aggregates."""
from __future__ import annotations

import numpy as np

from repro.relational.engine import (
    Aggregate,
    Filter,
    Join,
    Project,
    Scan,
    execute_plan,
)
from repro.relational.expr import Bin, Case, Col, Const, Un, eval_expr


def test_scan_project(credit_card):
    ds = credit_card
    plan = Project(Scan("transactions", ["v0", "v1"]), ["v0"], {})
    out = execute_plan(plan, ds.tables)
    assert set(out.columns) == {"v0"}
    np.testing.assert_allclose(
        np.asarray(out.columns["v0"]),
        ds.tables["transactions"]["v0"].astype(np.float32),
        rtol=1e-6,
    )


def test_fk_join_matches_oracle(expedia):
    ds = expedia
    plan = Scan("searches", list(ds.tables["searches"].keys()))
    for fact_col, dim, dim_col in ds.join_keys:
        cols = [c for c in ds.tables[dim] if c != dim_col]
        plan = Join(plan, dim, fact_col, dim_col, cols)
    out = execute_plan(plan, ds.tables)
    oracle = ds.joined_columns()
    valid = np.asarray(out.valid)
    assert valid.all()  # FK integrity: every key resolves
    for c in ("h_num0", "d_num0", "s_num0"):
        np.testing.assert_allclose(
            np.asarray(out.columns[c]), oracle[c].astype(np.float32), rtol=1e-5
        )


def test_filter_and_aggregate(hospital):
    ds = hospital
    t = ds.tables["patients"]
    plan = Aggregate(
        Filter(
            Scan("patients", ["age", "asthma"]),
            Bin("and", Bin("ge", Col("age"), Const(50.0)),
                Bin("eq", Col("asthma"), Const(1))),
        ),
        [("n", "count", "age"), ("mean_age", "mean", "age")],
    )
    out = execute_plan(plan, ds.tables)
    mask = (t["age"] >= 50) & (t["asthma"] == 1)
    assert int(np.asarray(out.columns["n"])[0]) == int(mask.sum())
    np.testing.assert_allclose(
        float(np.asarray(out.columns["mean_age"])[0]),
        t["age"][mask].mean(), rtol=1e-5,
    )


def test_expr_eval_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    env = {"x": x, "y": y}
    e = Case(
        Bin("gt", Col("x"), Const(0.0)),
        Bin("add", Bin("mul", Col("x"), Const(2.0)), Col("y")),
        Un("sigmoid", Col("y")),
    )
    got = np.asarray(eval_expr(e, env))
    want = np.where(x > 0, 2 * x + y, 1 / (1 + np.exp(-y)))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_expr_eval_deep_no_recursion_limit():
    # MLtoSQL emits 10k+-node expressions; evaluation must be stack-safe
    e = Col("x")
    for _ in range(30_000):
        e = Bin("add", e, Const(1.0))
    out = eval_expr(e, {"x": np.zeros(4, np.float32)})
    np.testing.assert_allclose(np.asarray(out), 30_000.0)
