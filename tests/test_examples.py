"""Examples smoke test: the quickstart and serving examples must keep
running against the current API (API drift in examples fails tier-1).

Each example honors RAVEN_EXAMPLE_N, so we run them small via subprocess.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(name: str, n: int = 512) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["RAVEN_EXAMPLE_N"] = str(n)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / name)],
        capture_output=True, text=True, timeout=600, env=env,
    )


@pytest.mark.parametrize("example", ["quickstart.py", "serve_query.py"])
def test_example_runs(example):
    proc = _run_example(example)
    assert proc.returncode == 0, (
        f"{example} failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}"
    )
    assert proc.stdout.strip()  # examples narrate what they do
