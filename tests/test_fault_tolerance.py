"""Fault tolerance: seeded injection, retry/backoff, breaker degradation.

The acceptance contract under test: with deterministic faults injected at
every instrumented site, the serving layer never hangs, never returns a
wrong result (transient faults recover to bitwise-identical outputs), and
every terminal failure surfaces as a *typed* error on exactly the affected
waiters.
"""
from __future__ import annotations

import numpy as np
import pytest

import repro as raven
from repro.data.datasets import make_hospital
from repro.exec.faults import FaultPlan, FaultSpec, get_fault_plan, set_fault_plan

SQL = "SELECT * FROM PREDICT(model='risk', data=patients) AS p"


def _batch(n: int, seed: int) -> dict[str, np.ndarray]:
    return make_hospital(n, seed=seed).tables["patients"]


def _serve(hospital, pipe, *, faults=None, retry=None, breaker_threshold=None,
           cache_dir=None, transform="none"):
    db = raven.connect(
        hospital.tables, stats="auto",
        options=raven.ConnectOptions(faults=faults, cache_dir=cache_dir),
    )
    db.models.publish("risk", pipe)
    prep = db.sql(SQL).prepare(transform=transform)
    prep.serve("q", options=raven.ServeOptions(
        retry=retry, breaker_threshold=breaker_threshold,
    ))
    return db, prep


@pytest.fixture(scope="module")
def baseline(hospital, hospital_dt):
    """No-fault ground truth for the host-boundary plan the matrix runs."""
    db, prep = _serve(hospital, hospital_dt)
    try:
        req = prep.submit(_batch(128, seed=21))
        db.flush()
        return np.asarray(req.wait(timeout=60.0)["score"])
    finally:
        db.close()


# -- the plan itself ---------------------------------------------------------

def test_fault_plan_is_deterministic():
    a = FaultPlan({"stage": {"rate": 0.5, "times": None}}, seed=9)
    b = FaultPlan({"stage": {"rate": 0.5, "times": None}}, seed=9)
    fired_a = [a.check("stage") is not None for _ in range(64)]
    fired_b = [b.check("stage") is not None for _ in range(64)]
    assert fired_a == fired_b          # pure function of (seed, site, index)
    assert any(fired_a) and not all(fired_a)
    c = FaultPlan({"stage": {"rate": 0.5}}, seed=10)
    assert [c.check("stage") is not None for _ in range(64)] != fired_a


def test_fault_plan_parse_env_format():
    plan = FaultPlan.parse("seed=7; stage:times=2; latency:delay_ms=50,rate=0.5")
    assert plan.seed == 7
    assert plan.specs == (
        FaultSpec(site="stage", times=2),
        FaultSpec(site="latency", delay_ms=50.0, rate=0.5),
    )
    with pytest.raises(ValueError, match="unknown site"):
        FaultPlan.parse("bogus:times=1")
    with pytest.raises(ValueError, match="unknown site"):
        FaultPlan({"bogus": {}})


def test_session_installs_and_clears_plan(hospital):
    plan = FaultPlan({"stage": {"times": 1}}, seed=1)
    db = raven.connect(
        hospital.tables, stats=None,
        options=raven.ConnectOptions(faults=plan),
    )
    assert get_fault_plan() is plan
    db.close()
    assert get_fault_plan() is None


# -- the matrix: every site, no hang, no wrong result ------------------------

@pytest.mark.parametrize("site", ["dispatch", "stage", "udf", "worker"])
def test_transient_fault_recovers_bitwise(site, hospital, hospital_dt, baseline):
    plan = FaultPlan({site: {"times": 2}}, seed=11)
    db, prep = _serve(
        hospital, hospital_dt, faults=plan,
        retry=raven.RetryPolicy(max_attempts=4, backoff_ms=0.25),
    )
    try:
        req = prep.submit(_batch(128, seed=21))
        db.flush()
        out = np.asarray(req.wait(timeout=60.0)["score"])
        assert plan.injected().get(site, 0) >= 1, "matrix leg was vacuous"
        assert np.array_equal(out, baseline)
        assert db.cache_stats()["server"]["faults_injected"] == plan.injected()
    finally:
        db.close()


def test_transient_compile_fault_recovers_bitwise(hospital, hospital_dt):
    # "compile" fires only when a stage actually traces a new XLA
    # specialization; compiled plans are cached process-wide by
    # fingerprint, so this leg needs a query no other test has compiled —
    # and the faulted session must run FIRST, while the trace is fresh
    sql = (
        "SELECT * FROM PREDICT(model='risk', data=patients) AS p "
        "WHERE p.age > 17.5"
    )
    plan = FaultPlan({"compile": {"times": 2}}, seed=11)
    batch = _batch(128, seed=21)

    db = raven.connect(
        hospital.tables, stats="auto",
        options=raven.ConnectOptions(faults=plan),
    )
    try:
        db.models.publish("risk", hospital_dt)
        prep = db.sql(sql).prepare(transform="none")
        prep.serve("q", options=raven.ServeOptions(
            retry=raven.RetryPolicy(max_attempts=4, backoff_ms=0.25),
        ))
        req = prep.submit(batch)
        db.flush()
        out = np.asarray(req.wait(timeout=60.0)["score"])
        assert plan.injected().get("compile", 0) >= 1, "leg was vacuous"
    finally:
        db.close()

    clean = raven.connect(hospital.tables, stats="auto")
    try:
        clean.models.publish("risk", hospital_dt)
        prep = clean.sql(sql).prepare(transform="none")
        prep.serve("q")
        req = prep.submit(batch)
        clean.flush()
        assert np.array_equal(out, np.asarray(req.wait(timeout=60.0)["score"]))
    finally:
        clean.close()


def test_latency_fault_stalls_but_answers(hospital, hospital_dt, baseline):
    plan = FaultPlan({"latency": {"delay_ms": 30.0, "times": 2}}, seed=5)
    db, prep = _serve(hospital, hospital_dt, faults=plan)
    try:
        req = prep.submit(_batch(128, seed=21))
        db.flush()
        out = np.asarray(req.wait(timeout=60.0)["score"])
        assert plan.injected().get("latency", 0) >= 1
        assert np.array_equal(out, baseline)
    finally:
        db.close()


def test_store_read_fault_falls_back_to_live_compile(
    tmp_path, hospital, hospital_dt, baseline
):
    # populate the store, then reconnect with every store read poisoned:
    # loads degrade to live compilation — counted, never caller-visible
    db, prep = _serve(hospital, hospital_dt, cache_dir=str(tmp_path / "c"))
    req = prep.submit(_batch(128, seed=21))
    db.flush()
    req.wait(timeout=60.0)
    db.close()

    plan = FaultPlan({"store-read": {}}, seed=2)
    db, prep = _serve(
        hospital, hospital_dt, faults=plan, cache_dir=str(tmp_path / "c"),
    )
    try:
        req = prep.submit(_batch(128, seed=21))
        db.flush()
        out = np.asarray(req.wait(timeout=60.0)["score"])
        assert np.array_equal(out, baseline)
        assert plan.injected().get("store-read", 0) >= 1
        store = db.cache_stats()["artifact_store"]
        assert store["corrupt"] >= 1 and store["fallbacks"] >= 1
    finally:
        db.close()


# -- terminal failures: typed, delivered, contained --------------------------

def test_terminal_fault_delivers_typed_error_to_every_waiter(
    hospital, hospital_dt, baseline
):
    plan = FaultPlan({"dispatch": {"times": 1, "transient": False}}, seed=3)
    db, prep = _serve(hospital, hospital_dt, faults=plan)
    try:
        # two requests on one bucket coalesce into the doomed group
        r1 = prep.submit(_batch(128, seed=21))
        r2 = prep.submit(_batch(128, seed=22))
        with pytest.raises(raven.FaultInjectedError):
            db.flush()
        for r in (r1, r2):
            with pytest.raises(raven.FaultInjectedError):
                r.wait(timeout=5.0)
        # the fault is spent: the route keeps serving, results exact
        r3 = prep.submit(_batch(128, seed=21))
        db.flush()
        assert np.array_equal(
            np.asarray(r3.wait(timeout=60.0)["score"]), baseline
        )
    finally:
        db.close()


def test_retries_exhausted_raises_request_failed(hospital, hospital_dt):
    plan = FaultPlan({"stage": {"times": 10}}, seed=4)
    db, prep = _serve(
        hospital, hospital_dt, faults=plan,
        retry=raven.RetryPolicy(max_attempts=2, backoff_ms=0.25),
    )
    try:
        req = prep.submit(_batch(64, seed=1))
        with pytest.raises(raven.RequestFailedError):
            db.flush()
        with pytest.raises(raven.RequestFailedError) as ei:
            req.wait(timeout=5.0)
        assert ei.value.attempts == 2
        assert db.cache_stats()["server"]["retries_exhausted"] >= 1
    finally:
        db.close()


def test_wait_timeout_is_typed(hospital, hospital_dt):
    db, prep = _serve(hospital, hospital_dt)
    try:
        req = prep.submit(_batch(64, seed=1))  # nobody flushes
        with pytest.raises(raven.RequestTimeoutError):
            req.wait(timeout=0.05)
        db.flush()  # leave the queue clean for close()
        req.wait(timeout=30.0)
    finally:
        db.close()


# -- circuit breaker: degrade to the kernel-free fallback --------------------

def test_breaker_trips_and_degrades_bitwise(hospital, hospital_dt, baseline):
    plan = FaultPlan({"stage": {"times": 3, "transient": False}}, seed=6)
    db, prep = _serve(
        hospital, hospital_dt, faults=plan, breaker_threshold=3,
    )
    try:
        for i in range(3):
            r = prep.submit(_batch(128, seed=21))
            with pytest.raises(raven.FaultInjectedError):
                db.flush()
            with pytest.raises(raven.FaultInjectedError):
                r.wait(timeout=5.0)
        snap = db.server.route_snapshot("q")["versions"]["v1"]
        assert snap["degraded"] and snap["breaker_trips"] == 1
        # degraded traffic serves the kernel-free fallback, bitwise equal
        # (kernel parity contract)
        r = prep.submit(_batch(128, seed=21))
        db.flush()
        assert np.array_equal(
            np.asarray(r.wait(timeout=60.0)["score"]), baseline
        )
        stats = db.cache_stats()["server"]
        assert stats["breaker_trips"] == 1
        from repro.analysis.registry_check import check_fault_tolerance

        assert check_fault_tolerance(db) == []
    finally:
        db.close()


def test_breaker_success_resets_failure_count(hospital, hospital_dt):
    plan = FaultPlan({"stage": {"times": 1, "transient": False}}, seed=8)
    db, prep = _serve(
        hospital, hospital_dt, faults=plan, breaker_threshold=2,
    )
    try:
        r = prep.submit(_batch(64, seed=1))
        with pytest.raises(raven.FaultInjectedError):
            db.flush()
        r2 = prep.submit(_batch(64, seed=1))
        db.flush()
        r2.wait(timeout=60.0)
        snap = db.server.route_snapshot("q")["versions"]["v1"]
        assert snap["breaker_failures"] == 0 and not snap["degraded"]
    finally:
        db.close()


# -- env-var plan ------------------------------------------------------------

def test_env_fault_plan(hospital, hospital_dt, monkeypatch, baseline):
    monkeypatch.setenv("RAVEN_FAULTS", "seed=12;stage:times=1")
    assert get_fault_plan() is not None
    db, prep = _serve(
        hospital, hospital_dt,
        retry=raven.RetryPolicy(max_attempts=3, backoff_ms=0.25),
    )
    try:
        req = prep.submit(_batch(128, seed=21))
        db.flush()
        out = np.asarray(req.wait(timeout=60.0)["score"])
        assert np.array_equal(out, baseline)
        assert db.cache_stats()["server"]["retries"] >= 1
    finally:
        db.close()
        monkeypatch.delenv("RAVEN_FAULTS")
        set_fault_plan(None)
