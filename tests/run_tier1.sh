#!/usr/bin/env bash
# Tier-1 gate: the fast suite, pinned to the in-repo sources.
#
#   tests/run_tier1.sh [extra pytest args]
#
# Excludes @pytest.mark.slow (corpus/strategy training — minutes of model
# fitting) so the gate runs in minutes on every PR; the full suite is just
# `python -m pytest` without the marker filter.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Every plan the suite prepares passes strict static verification: a rewrite
# or lowering that breaks a verifier invariant fails the gate with the
# offending rule named, not just with whatever downstream symptom it causes.
export RAVEN_VERIFY=strict
exec python -m pytest -x -q -m "not slow" "$@"
