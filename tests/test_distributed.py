"""Multi-device behaviour via subprocesses (XLA_FLAGS must precede jax init,
so the main pytest process stays single-device)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_sharded_plan_equals_single_device(hospital, tmp_path):
    """MLtoSQL-fused plan under shard_map over 8 devices == 1-device result."""
    from repro.core.optimizer import OptimizerOptions, RavenOptimizer
    from repro.relational.engine import execute_plan
    from repro.sql.parser import parse_prediction_query
    from tests.conftest import train_pipeline
    from repro.ml.pipeline import save_pipeline

    pipe = train_pipeline(hospital, "dt")
    mpath = str(tmp_path / "m.npz")
    save_pipeline(pipe, mpath)
    np.savez(str(tmp_path / "data.npz"), **hospital.tables["patients"])

    sql = "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) AS p WHERE score >= 0.5"
    q = parse_prediction_query(sql, {"m": pipe}, hospital.tables)
    plan, _ = RavenOptimizer(
        options=OptimizerOptions(transform="sql")
    ).optimize(q)
    ref = float(
        np.asarray(
            execute_plan(plan, hospital.tables).columns["count_rows"]
        )[0]
    )

    out = _run_py(f"""
        import numpy as np, jax
        from repro.ml.pipeline import load_pipeline
        from repro.sql.parser import parse_prediction_query
        from repro.core.optimizer import OptimizerOptions, RavenOptimizer
        from repro.relational.engine import compile_plan_sharded

        data = dict(np.load({str(tmp_path / 'data.npz')!r}))
        pipe = load_pipeline({mpath!r})
        db = {{'patients': data}}
        sql = {sql!r}
        q = parse_prediction_query(sql, {{'m': pipe}}, db)
        plan, _ = RavenOptimizer(options=OptimizerOptions(transform='sql')).optimize(q)
        mesh = jax.make_mesh((8,), ('data',))
        run = compile_plan_sharded(plan, mesh, fact_table='patients')
        out = run(db)
        print('COUNT=', float(np.asarray(out.columns['count_rows'])[0]))
    """)
    got = float(out.split("COUNT=")[1].strip())
    assert got == ref


def test_hierarchical_psum_matches_flat():
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import hierarchical_psum

        mesh = jax.make_mesh((2, 4), ('pod', 'data'))
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)

        def flat(v):
            return jax.lax.psum(v, ('pod', 'data'))

        def hier(v):
            return hierarchical_psum(v, intra_axis='data', inter_axis='pod')

        fa = shard_map(flat, mesh=mesh, in_specs=P(('pod','data'), None),
                       out_specs=P(('pod','data'), None))(x)
        fb = shard_map(hier, mesh=mesh, in_specs=P(('pod','data'), None),
                       out_specs=P(('pod','data'), None))(x)
        print('MATCH=', bool(jnp.allclose(fa, fb)))
    """)
    assert "MATCH= True" in out


def test_embed_lookup_vocab_sharded_matches_take():
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.transformer import embed_lookup
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        V, D, B, S = 64, 16, 4, 8
        embed = jax.random.normal(jax.random.PRNGKey(0), (V, D), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
        with jax.set_mesh(mesh) if hasattr(jax, 'set_mesh') else mesh:
            got = embed_lookup(embed, toks, mesh)
        want = jnp.take(embed, toks, axis=0)
        print('MATCH=', bool(jnp.allclose(got, want, atol=1e-6)))
        # B=1 path (long_500k): batch not divisible by data axis
        toks1 = toks[:1]
        with mesh:
            got1 = embed_lookup(embed, toks1, mesh)
        print('MATCH1=', bool(jnp.allclose(got1, jnp.take(embed, toks1, axis=0), atol=1e-6)))
    """)
    assert "MATCH= True" in out and "MATCH1= True" in out


def test_compressed_allreduce_inside_shard_map():
    out = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import ef_init, compressed_gradient_update

        mesh = jax.make_mesh((4,), ('pod',))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 32), jnp.float32)

        def body(gl):
            state = ef_init({'g': gl})
            out, _ = compressed_gradient_update({'g': gl}, state, axis_name='pod')
            return out['g']

        got = shard_map(body, mesh=mesh, in_specs=P('pod', None),
                        out_specs=P('pod', None))(g)
        want = jnp.mean(g, axis=0, keepdims=True)  # psum/4 of per-pod grads
        err = float(jnp.abs(got - want).max())
        scale = float(jnp.abs(g).max()) / 127.0
        print('OK=', err <= 2.1 * scale)
    """)
    assert "OK= True" in out
