"""The pipelined executor + multi-queue scheduler: concurrency stress,
backpressure, fairness, and the zero-warm-trace invariant.

The serving contract under test: threaded submitters against multiple
queues never lose or misroute a result; a bounded queue rejects (or blocks)
submits at ``max_pending``; a small latency-targeted query keeps a bounded
p99 while a large coalesced group is in flight; and pipelined execution
runs the *same* jit specializations as the serial path, so previously
served buckets never re-trace (asserted via ``db.cache_stats()``).
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

import repro as raven
from repro.data.datasets import make_hospital
from repro.errors import ServerOverloadedError
from repro.exec.scheduler import Scheduler
from repro.relational.engine import clear_plan_cache
from repro.serve import PredictionQueryServer

SQL = "SELECT * FROM PREDICT(model='m', data=patients) AS p WHERE score >= :t"


@pytest.fixture()
def db(hospital, hospital_dt):
    sess = raven.connect(hospital.tables, stats="auto")
    sess.register_model("m", hospital_dt)
    yield sess
    sess.close()


def _batch(n, seed):
    return make_hospital(n, seed=seed).tables["patients"]


# ---------------------------------------------------------------------------
# Scheduler unit behavior
# ---------------------------------------------------------------------------


class _Req:
    def __init__(self, rid, t_submit):
        self.rid = rid
        self.t_submit = t_submit


def _pop(sch: Scheduler, q):
    with sch._cv:  # _pop_group's contract: caller holds the scheduler lock
        group, _attempt = sch._pop_group(q)
        return group


def test_pop_group_respects_coalesce_cap():
    sch = Scheduler(lambda name, group: None, default_coalesce=100)
    now = time.perf_counter()
    for i, n in enumerate((40, 40, 40, 200, 10)):
        sch.enqueue("q", _Req(i, now), n)
    q = sch._queues["q"]
    # 40+40 fits, +40 would exceed 100
    assert [r.rid for r in _pop(sch, q)] == [0, 1]
    assert [r.rid for r in _pop(sch, q)] == [2]  # 40+200 > 100
    assert [r.rid for r in _pop(sch, q)] == [3]  # oversize pops alone
    assert [r.rid for r in _pop(sch, q)] == [4]


def test_edf_picks_tightest_deadline_and_rotates_overdue():
    sch = Scheduler(lambda name, group: None)
    sch.configure("bulk", max_latency_ms=50.0)
    sch.configure("fast", max_latency_ms=5.0)
    t0 = time.perf_counter()
    sch.enqueue("bulk", _Req(0, t0), 1)
    sch.enqueue("fast", _Req(1, t0 + 0.010), 1)
    # before anything is overdue: fast's 15ms deadline < bulk's 50ms
    assert sch._earliest(now=t0 + 0.012).name == "fast"
    # both long overdue: least-recently-served wins, and alternates
    far = t0 + 10.0
    first = sch._earliest(now=far)
    _pop(sch, first)
    sch.enqueue(first.name, _Req(2, t0), 1)
    assert sch._earliest(now=far).name != first.name


def test_backpressure_blocks_then_raises_on_timeout():
    sch = Scheduler(lambda name, group: None)
    sch.configure("q", max_pending=2)
    now = time.perf_counter()
    sch.enqueue("q", _Req(0, now), 1)
    sch.enqueue("q", _Req(1, now), 1)
    with pytest.raises(ServerOverloadedError, match="max_pending=2"):
        sch.enqueue("q", _Req(2, now), 1, block=False)
    t0 = time.perf_counter()
    with pytest.raises(ServerOverloadedError):
        sch.enqueue("q", _Req(2, now), 1, timeout=0.15)
    assert time.perf_counter() - t0 >= 0.1  # actually waited
    assert sch.overloads == 2 and sch.backpressure_waits == 1
    # a concurrent pop unblocks a waiting submitter
    unblocked = threading.Event()

    def submitter():
        sch.enqueue("q", _Req(3, time.perf_counter()), 1, timeout=5.0)
        unblocked.set()

    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.05)
    _pop(sch, sch._queues["q"])
    t.join(5.0)
    assert unblocked.is_set()


def test_blocking_submit_without_pump_fails_fast_instead_of_deadlocking():
    # block=True + timeout=None + no pump thread: nothing can ever free the
    # queue (flush() is unreachable from the blocked caller) — must raise,
    # not hang
    sch = Scheduler(lambda name, group: None)
    sch.configure("q", max_pending=1)
    sch.enqueue("q", _Req(0, time.perf_counter()), 1)
    with pytest.raises(ServerOverloadedError, match="no pump thread"):
        sch.enqueue("q", _Req(1, time.perf_counter()), 1)


def test_drain_waits_for_groups_the_pump_already_took():
    # the pump pops a group and its (slow) dispatch is still in flight when
    # drain() runs on an empty queue: drain must wait for it, preserving
    # the "submit, flush, read the result" contract
    from concurrent.futures import Future

    done = threading.Event()

    def slow_dispatch(name, group):
        fut: Future = Future()

        def finish():
            time.sleep(0.2)
            for r in group:
                r.served = True
            done.set()
            fut.set_result(group)

        threading.Thread(target=finish, daemon=True).start()
        return fut

    sch = Scheduler(slow_dispatch, default_latency_ms=1.0)
    sch.start()
    try:
        req = _Req(0, time.perf_counter())
        req.served = False
        sch.enqueue("q", req, 1)
        # wait until the pump has popped it (queue empty, group in flight)
        deadline = time.time() + 5.0
        while sch.depths().get("q") and time.time() < deadline:
            time.sleep(0.005)
        sch.drain()
        assert req.served, "drain returned before the in-flight group settled"
        assert done.is_set()
    finally:
        sch.stop()


# ---------------------------------------------------------------------------
# Server-level backpressure
# ---------------------------------------------------------------------------


def test_submit_overload_raises_and_recovers(db):
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.6}).serve(
        name="bounded", max_pending=2,
    )
    r1 = prep.submit(_batch(8, seed=1))
    r2 = prep.submit(_batch(8, seed=2))
    with pytest.raises(ServerOverloadedError, match="bounded"):
        prep.submit(_batch(8, seed=3), block=False)
    with pytest.raises(ServerOverloadedError):
        prep.submit(_batch(8, seed=3), timeout=0.05)
    db.flush()  # frees the queue
    assert r1.done and r2.done
    r3 = prep.submit(_batch(8, seed=3), block=False)
    db.flush()
    assert r3.done
    stats = db.cache_stats()["server"]
    assert stats["overloads"] >= 2
    assert stats["max_queue_depth"] >= 2


def test_blocked_submit_proceeds_when_pump_frees_space(db):
    prep = db.sql(SQL).prepare(transform="sql", params={"t": 0.6}).serve(
        name="bounded2", max_pending=1, max_latency_ms=5,
    )
    reqs = [prep.submit(_batch(16, seed=i), timeout=30.0) for i in range(6)]
    outs = [r.wait(timeout=30.0) for r in reqs]
    assert all(o is not None for o in outs)


# ---------------------------------------------------------------------------
# Concurrency stress: no lost or misrouted results across queries
# ---------------------------------------------------------------------------


def test_threaded_submitters_two_queries_no_lost_or_misrouted(db):
    # one pure query and one UDF (host-boundary) query served from the same
    # scheduler; 4 submitter threads interleave batches whose 'age' column
    # encodes (thread, sequence) so any misrouting/mixup is detectable
    pure = db.sql(SQL).prepare(transform="sql", params={"t": -1e9}).serve(
        name="pure_q", max_latency_ms=3,
    )
    udf = db.sql(SQL).prepare(transform="none", params={"t": -1e9}).serve(
        name="udf_q", max_latency_ms=3,
    )
    n_threads, n_per = 4, 6
    results: dict[tuple, tuple] = {}
    errors: list[BaseException] = []
    lock = threading.Lock()

    def submitter(tid):
        try:
            for i in range(n_per):
                n = 16 + 8 * ((tid + i) % 3)
                b = dict(_batch(n, seed=100 + tid * 31 + i))
                tag = float(1000 * tid + i)
                b["age"] = np.full(n, tag)
                prep = pure if (tid + i) % 2 == 0 else udf
                req = prep.submit(b)
                out = req.wait(timeout=60.0)
                with lock:
                    results[(tid, i)] = (tag, n, out)
        except BaseException as e:  # pragma: no cover - the assertion target
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == n_threads * n_per  # nothing lost
    for (tid, i), (tag, n, out) in results.items():
        # threshold -1e9 keeps every row, so each request must get exactly
        # its own rows back — its tag, all n of them, nobody else's
        assert len(out["age"]) == n, (tid, i)
        np.testing.assert_array_equal(np.unique(out["age"]), [tag])


def test_small_query_p99_bounded_while_bulk_group_in_flight(db):
    # a large coalesced UDF group occupies the boundary pool; the small
    # pure query must keep flowing on its own deadline instead of queueing
    # behind the bulk work (EDF + overdue rotation + pipelined dispatch)
    bulk = db.sql(SQL).prepare(transform="none", params={"t": 0.6}).serve(
        name="bulk", max_latency_ms=100, max_coalesce=1500,
    )
    small = db.sql(SQL).prepare(transform="sql", params={"t": 0.6}).serve(
        name="small", max_latency_ms=5,
    )
    bulk.submit(_batch(1500, seed=0)).wait(timeout=60)  # warm bulk bucket
    small.submit(_batch(32, seed=1)).wait(timeout=60)   # warm small bucket
    bulk_reqs = [bulk.submit(_batch(1500, seed=10 + i)) for i in range(4)]
    lats = []
    for i in range(10):
        r = small.submit(_batch(32, seed=50 + i))
        r.wait(timeout=60.0)
        lats.append(r.latency_s)
        time.sleep(0.005)
    for r in bulk_reqs:
        r.wait(timeout=120.0)
    stats = db.cache_stats()["server"]
    assert stats["pipeline"]["overlapped_groups"] >= 1
    # generous bound for loaded CI boxes: the serial pump would hold every
    # small behind a full bulk-group execution (hundreds of ms); pipelined
    # dispatch keeps the p99 within tens of ms of the 5 ms target
    p99 = sorted(lats)[-1]
    assert p99 < 0.5, f"small-query p99 {p99 * 1e3:.1f}ms — starved by bulk"


# ---------------------------------------------------------------------------
# Acceptance: pipelined serving preserves the zero-warm-trace invariant
# ---------------------------------------------------------------------------


def test_pipelined_execution_zero_new_traces_on_warm_buckets(db):
    clear_plan_cache()
    prep = db.sql(SQL).prepare(transform="none", params={"t": 0.6}).serve(
        name="warm_udf", max_latency_ms=3,
    )
    # warm through the pipelined pump path itself
    prep.submit(_batch(100, seed=1)).wait(timeout=60.0)
    warm = db.cache_stats()
    assert warm["traces"] >= 2
    for i, n in enumerate((65, 128, 80, 127)):  # all land in bucket 128
        # one request per group (like the serial warm test): a burst would
        # coalesce into a segmented group, which is a different — equally
        # cacheable, but separately warmed — program shape
        prep.submit(_batch(n, seed=30 + i)).wait(timeout=60.0)
    stats = db.cache_stats()
    assert stats["traces"] == warm["traces"], (
        "pipelined serving re-traced a previously-served bucket"
    )
    assert stats["stage_traces"] == warm["stage_traces"]
    assert stats["server"]["pipelined_groups"] >= 1


def test_serial_and_pipelined_results_identical(db):
    batches = [_batch(n, seed=60 + i) for i, n in enumerate((40, 90, 170))]
    outs = {}
    for mode in (False, True):
        srv = PredictionQueryServer(pipelined=mode)
        prep = db.sql(SQL).prepare(transform="none", params={"t": 0.6}).serve(
            name="ab", server=srv,
        )
        reqs = [prep.submit(b) for b in batches]
        srv.flush()
        outs[mode] = [r.result for r in reqs]
        srv.shutdown()
    for a, b in zip(outs[False], outs[True]):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6)


def test_forced_donation_split_matches_plain(db, monkeypatch):
    """RAVEN_DONATE=1 exercises the donating volatile/resident jit split on
    CPU (jax warns the donation was unusable; results must be identical)."""
    import warnings

    ref_srv = PredictionQueryServer()
    db.sql(SQL).prepare(transform="sql", params={"t": 0.6}).serve(
        name="don_ref", server=ref_srv,
    )
    b = _batch(200, seed=9)
    ref = ref_srv.execute("don_ref", b)
    monkeypatch.setenv("RAVEN_DONATE", "1")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        don_srv = PredictionQueryServer()
        db.sql(SQL).prepare(transform="sql", params={"t": 0.6}).serve(
            name="don_on", server=don_srv,
        )
        got = don_srv.execute("don_on", b)
    assert set(ref) == set(got)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-6)