"""Hypothesis property: validity-mask correctness across host boundaries.

Padded, bucketed, stage-by-stage serving of a Join → Predict(UDF) → Filter
plan must be row-for-row equal to unpadded ``execute_plan`` — for any batch
size (hence any entry/mid bucket padding) and any row sample. This is the
invariant the whole bucketed serving layer rests on: pad rows are carried as
``valid=False`` through joins, the host boundary's compaction, the post-UDF
re-padding, and the final filter, and never leak into results.
"""
from __future__ import annotations

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ir import TableStats
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.data.datasets import make_expedia
from repro.relational.engine import MLUdf, execute_plan, walk_plan
from repro.serve import PredictionQueryServer
from repro.sql.parser import parse_prediction_query
from tests.conftest import train_pipeline


@pytest.fixture(scope="module")
def expedia_served():
    ds = make_expedia(1024, seed=2)
    pipe = train_pipeline(ds, "dt")
    query = parse_prediction_query(
        "SELECT * FROM PREDICT(model='m', data=searches "
        "JOIN hotels ON hotel_id = hotel_id "
        "JOIN destinations ON dest_id = dest_id) AS p "
        "WHERE score >= 0.5",
        {"m": pipe}, ds.tables,
        stats={t: TableStats.of(cols) for t, cols in ds.tables.items()},
    )
    plan, _ = RavenOptimizer(
        options=OptimizerOptions(transform="none")
    ).optimize(query)
    assert any(isinstance(p, MLUdf) for p in walk_plan(plan))
    srv = PredictionQueryServer(
        options=OptimizerOptions(transform="none"), min_bucket=16,
    )
    srv.register("q", query, ds.tables)
    return ds, plan, srv


@settings(max_examples=12, deadline=None)
@given(n=st.integers(min_value=1, max_value=400), seed=st.integers(0, 2**16))
def test_masked_bucketed_join_udf_filter_equals_unpadded(expedia_served, n, seed):
    ds, plan, srv = expedia_served
    rng = np.random.default_rng(seed)
    base = ds.tables["searches"]
    idx = rng.integers(0, len(next(iter(base.values()))), size=n)
    rows = {c: np.asarray(v)[idx] for c, v in base.items()}
    got = srv.execute("q", rows)
    tables = {t: dict(cols) for t, cols in ds.tables.items()}
    tables["searches"] = rows
    ref = execute_plan(plan, tables).to_numpy()
    assert set(ref) <= set(got)
    for k in ref:
        assert got[k].shape == ref[k].shape  # row-for-row, same compaction
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, atol=1e-6)
