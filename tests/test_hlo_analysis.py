"""Executed-cost HLO analyzer: exact on known programs, trip-count scaling."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    cost = analyze_hlo(c.as_text())
    assert cost.flops == 2 * 64 * 32 * 128


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        c, _ = jax.lax.scan(body, x, None, length=11)
        return c

    cost = analyze_hlo(_compile(f, x).as_text())
    assert cost.flops == 11 * 2 * 32 * 32 * 32
    assert cost.unknown_trip_loops == 0


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    cost = analyze_hlo(_compile(f, x).as_text())
    assert cost.flops == 5 * 3 * 2 * 16 * 16 * 16


def test_bytes_scale_with_loop():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def loop(x, n):
        def body(c, _):
            return c + 1.0, None
        c, _ = jax.lax.scan(body, x, None, length=n)
        return c

    c2 = analyze_hlo(_compile(lambda v: loop(v, 2), x).as_text())
    c20 = analyze_hlo(_compile(lambda v: loop(v, 20), x).as_text())
    assert c20.bytes > 5 * c2.bytes  # ~10x modulo loop-invariant bits


def test_grad_counts_forward_and_backward():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    fwd = analyze_hlo(_compile(loss, w, x).as_text())
    bwd = analyze_hlo(_compile(jax.grad(loss), w, x).as_text())
    # grad w.r.t. w = forward matmul + one dw matmul -> exactly 2x
    assert bwd.flops == 2 * fwd.flops
