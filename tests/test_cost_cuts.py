"""Cost-based pipeline cuts: the cost model judges the structural split
against the monolithic host lowering.

Property: for any pipeline and any (positive-rate) cost model, the chosen
cut never has more host boundaries than the structural cut — both admitted
candidates carry exactly one, so cost-based selection can reshape the plan
but never add a boundary. A seeded regression pins the flip: a model that
prices boundary crossings sky-high collapses the split to one monolithic
MLUdf whose results are bit-identical to host ``run_pipeline``. Calibration
consumes the same per-stage dispatch timings ``explain()`` renders.
"""
from __future__ import annotations

import numpy as np
import pytest

import repro as raven
from repro.core.cost import CostModel
from repro.core.optimizer import OptimizerOptions
from repro.core.rules.ml_to_dnn import compile_pipeline_to_dnn_partial
from repro.ml.pipeline import (
    InputSpec,
    PipelineNode,
    TrainedPipeline,
    run_pipeline,
    select_cut,
    split_pipeline,
)
from repro.relational.engine import MLUdf, TensorOp, clear_plan_cache, walk_plan
from repro.tensor.compile import tensor_supported


def _udf(X):
    return (X.astype(np.float32) * np.float32(0.5)) + np.float32(0.25)


_udf.__fingerprint_token__ = "test-cost-cuts-udf-v1"


def _build(k: int, udf_pos: str) -> TrainedPipeline:
    """k numeric inputs → concat → scaler → feature_extractor with a
    python_udf at ``udf_pos`` (same shapes as the split-lowering suite)."""
    xs = [f"x{i}" for i in range(k)]
    nodes: list[PipelineNode] = []
    off = np.zeros(k, np.float32)
    sc = np.ones(k, np.float32)
    if udf_pos == "start":
        nodes.append(PipelineNode("python_udf", [xs[0]], ["h0"], {"fn": _udf}))
        concat_in = ["h0", *xs[1:]]
    else:
        concat_in = list(xs)
    nodes.append(PipelineNode("concat", concat_in, ["raw"]))
    if udf_pos == "middle":
        nodes.append(PipelineNode("python_udf", ["raw"], ["raw_h"], {"fn": _udf}))
        scaler_in = "raw_h"
    else:
        scaler_in = "raw"
    nodes.append(
        PipelineNode("scaler", [scaler_in], ["scaled"],
                     {"offset": off, "scale": sc})
    )
    nodes.append(
        PipelineNode("feature_extractor", ["scaled"], ["feat"], {"indices": [0]})
    )
    final = "feat"
    if udf_pos == "end":
        nodes.append(PipelineNode("python_udf", ["feat"], ["feat_h"], {"fn": _udf}))
        final = "feat_h"
    return TrainedPipeline(
        inputs=[InputSpec(x, "numeric") for x in xs],
        outputs=[final],
        nodes=nodes,
    )


def _n_host(plan) -> int:
    return sum(1 for s in walk_plan(plan) if isinstance(s, MLUdf))


def _random_model(rng) -> CostModel:
    """A cost model with arbitrary (but positive) rates — including regimes
    that flip the decision either way."""
    m = CostModel()
    for d in (m.host_ns, m.tensor_ns):
        for kind in d:
            d[kind] *= float(rng.uniform(0.01, 100.0))
    m.crossing_ns_per_row = float(rng.uniform(1.0, 1e7))
    m.segment_fixed_us = float(rng.uniform(1.0, 1e6))
    m.rows_hint = int(rng.integers(1, 100_000))
    return m


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("udf_pos", ["start", "middle", "end"])
def test_chosen_cut_never_adds_host_boundaries(seed, udf_pos):
    """Property: across random cost models, the cost-chosen plan has at most
    as many host boundaries as the structural split's plan."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 5))
    pipe = _build(k, udf_pos)
    data = {f"x{i}": (rng.integers(-40, 40, 64) * 0.25).astype(np.float32)
            for i in range(k)}
    model = _random_model(rng)

    db = raven.connect({"t": data})
    db.register_model("m", pipe)
    # projection pushdown can't width-infer through a python_udf feeding a
    # concat (the "start" shape) — not what this property is about
    common = dict(transform="dnn", projection_pushdown=False)
    clear_plan_cache()
    structural = db.table("t").predict("m").prepare(
        options=OptimizerOptions(
            cost_model=CostModel(crossing_ns_per_row=0.0, segment_fixed_us=0.0),
            **common,
        )
    )
    clear_plan_cache()
    chosen = db.table("t").predict("m").prepare(
        options=OptimizerOptions(cost_model=model, **common)
    )
    assert _n_host(chosen.plan) <= _n_host(structural.plan) == 1
    # and the chosen plan still computes the same thing, bit for bit
    host = run_pipeline(pipe, data)
    want = np.asarray(host[pipe.outputs[0]], np.float32).reshape(-1)
    for prep in (structural, chosen):
        got = np.asarray(prep(data)["score"], np.float32).reshape(-1)
        assert np.array_equal(want.view(np.uint32), got.view(np.uint32))
    db.close()
    clear_plan_cache()


def test_decision_candidates_respect_residual_minimal():
    """select_cut only ever returns the structural split (whose residual is
    the minimal unsupported set) or a monolithic decision — it never demotes
    supported ops into a larger residual."""
    pipe = _build(3, "middle")
    structural = split_pipeline(pipe, tensor_supported)
    for model in (CostModel.default(),
                  CostModel(crossing_ns_per_row=1e8, segment_fixed_us=1e7)):
        split, decision = select_cut(pipe, tensor_supported, cost_model=model)
        assert split.placement == structural.placement
        assert decision.choice in ("split", "monolithic")


def test_fully_supported_pipeline_has_no_decision():
    pipe = TrainedPipeline(
        inputs=[InputSpec("a", "numeric")],
        outputs=["s"],
        nodes=[PipelineNode("scaler", ["a"], ["s"],
                            {"offset": np.zeros(1, np.float32),
                             "scale": np.ones(1, np.float32)})],
    )
    split, decision = select_cut(pipe, tensor_supported)
    assert split.fully_supported and decision is None
    part = compile_pipeline_to_dnn_partial(pipe)
    assert part.full is not None and part.decision is None


def test_seeded_cost_flip_monolithic_bitwise():
    """Regression: a boundary-hostile cost model flips the cut from split to
    monolithic; plan shape changes, results stay bit-identical to host
    ``run_pipeline``, and ``explain()`` narrates the decision."""
    rng = np.random.default_rng(42)
    pipe = _build(2, "middle")
    data = {f"x{i}": (rng.integers(-40, 40, 200) * 0.25).astype(np.float32)
            for i in range(2)}
    db = raven.connect({"t": data})
    db.register_model("m", pipe)

    clear_plan_cache()
    split_prep = db.table("t").predict("m").prepare(transform="dnn")
    kinds = [type(s).__name__ for s in walk_plan(split_prep.plan)
             if isinstance(s, (MLUdf, TensorOp))]
    assert kinds == ["TensorOp", "MLUdf", "TensorOp"]
    assert "cost-based cut: kept the structural split" in split_prep.explain()

    flip = CostModel(crossing_ns_per_row=1e7, segment_fixed_us=1e6)
    clear_plan_cache()
    mono_prep = db.table("t").predict("m").prepare(
        options=OptimizerOptions(transform="dnn", cost_model=flip)
    )
    kinds = [type(s).__name__ for s in walk_plan(mono_prep.plan)
             if isinstance(s, (MLUdf, TensorOp))]
    assert kinds == ["MLUdf"]
    udf = next(s for s in walk_plan(mono_prep.plan) if isinstance(s, MLUdf))
    assert len(udf.pipeline.nodes) == len(pipe.nodes)  # whole pipeline, host
    text = mono_prep.explain()
    assert "collapsed the split to one monolithic host UDF" in text
    assert "all 4 ops on host" in text

    host = run_pipeline(pipe, data)
    want = np.asarray(host[pipe.outputs[0]], np.float32).reshape(-1)
    for prep in (split_prep, mono_prep):
        got = np.asarray(prep(data)["score"], np.float32).reshape(-1)
        assert np.array_equal(want.view(np.uint32), got.view(np.uint32))
    db.close()
    clear_plan_cache()


def test_calibration_from_served_graph_timings():
    """calibrate_from_graph consumes the Stage.calls/total_s accounting that
    ``explain()`` renders, rescales the touched per-op host rates, and is
    deterministic for a given set of timings."""
    rng = np.random.default_rng(0)
    pipe = _build(2, "middle")
    data = {f"x{i}": (rng.integers(-40, 40, 500) * 0.25).astype(np.float32)
            for i in range(2)}
    db = raven.connect({"t": data})
    db.register_model("m", pipe)
    clear_plan_cache()
    prep = db.table("t").predict("m").prepare(transform="dnn")
    prep(data)  # populate stage timings
    graph = prep.compiled.graph
    assert any(s.calls for s in graph.stages)

    model = CostModel.default()
    before = dict(model.host_ns)
    observed = model.calibrate_from_graph(graph, rows=500)
    assert observed >= 1  # at least the host residual stage
    assert model.host_ns["python_udf"] != before["python_udf"]
    # deterministic: same graph timings → same calibrated rates
    model2 = CostModel.default()
    model2.calibrate_from_graph(graph, rows=500)
    assert model2.host_ns == model.host_ns

    # a calibrated model feeds straight back into prepare()
    clear_plan_cache()
    prep2 = db.table("t").predict("m").prepare(
        options=OptimizerOptions(transform="dnn", cost_model=model)
    )
    assert "cost-based cut" in prep2.explain()
    db.close()
    clear_plan_cache()


def test_default_model_keeps_plan_fingerprint_stable():
    """options.cost_model=None lowers with a fresh default model — two
    prepares of the same query produce identical plan fingerprints (the
    disk plan cache must not fork on the default)."""
    rng = np.random.default_rng(1)
    pipe = _build(2, "middle")
    data = {f"x{i}": (rng.integers(-40, 40, 64) * 0.25).astype(np.float32)
            for i in range(2)}
    db = raven.connect({"t": data})
    db.register_model("m", pipe)
    clear_plan_cache()
    a = db.table("t").predict("m").prepare(transform="dnn").fingerprint
    clear_plan_cache()
    b = db.table("t").predict("m").prepare(transform="dnn").fingerprint
    assert a == b
    db.close()
    clear_plan_cache()
