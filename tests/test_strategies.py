"""Runtime-selection strategies (paper §5.2) over a miniature corpus."""
from __future__ import annotations

import numpy as np
import pytest

# building the corpus trains + measures 24 pipelines across all three
# runtimes — minutes of work, excluded from the tier-1 gate (-m "not slow")
pytestmark = pytest.mark.slow

from repro.core.corpus import build_corpus
from repro.core.strategies import (
    ClassificationStrategy,
    RegressionStrategy,
    RuleBasedStrategy,
    TRANSFORMS,
    evaluate_strategy,
)


@pytest.fixture(scope="module")
def corpus():
    # small corpus: strategy machinery, not statistical power, is under test
    return build_corpus(n_pipelines=24, n_rows=2000, seed=7)


def test_corpus_shapes(corpus):
    assert corpus.stats.shape == (24, 22)
    assert corpus.runtimes.shape == (24, 3)
    assert set(np.unique(corpus.labels)) <= {0, 1, 2}
    assert np.isfinite(corpus.runtimes[:, [0, 2]]).all()  # none/dnn always run


@pytest.mark.parametrize(
    "cls", [RuleBasedStrategy, ClassificationStrategy, RegressionStrategy]
)
def test_strategy_fit_and_choose(corpus, cls):
    if cls is RegressionStrategy:
        s = cls().fit(corpus.stats, corpus.runtimes)
    else:
        s = cls().fit(corpus.stats, corpus.labels)
    choices = [s.choose(x) for x in corpus.stats]
    assert all(c in TRANSFORMS for c in choices)
    # the strategy must beat always-worst by construction on training data
    res = evaluate_strategy(s, corpus.stats, corpus.labels, corpus.runtimes)
    worst = corpus.runtimes.max(axis=1).sum()
    chosen = corpus.runtimes[
        np.arange(len(choices)), [TRANSFORMS.index(c) for c in choices]
    ].sum()
    assert chosen <= worst
    assert res["speedup_vs_optimal"] <= 1.0 + 1e-9
    assert res["accuracy"] >= 0.3


def test_rule_based_renders_readable_rule(corpus):
    s = RuleBasedStrategy(k=3).fit(corpus.stats, corpus.labels)
    text = s.describe()
    # always renders at least the leaf actions; splits (when the labels are
    # not single-class) reference real statistic names
    assert "apply " in text
    if "if " in text:
        from repro.core.stats import STAT_NAMES

        assert any(name in text for name in STAT_NAMES)


def test_strategies_beat_majority_class(corpus):
    """The learned strategies must at least match the majority-label rule on
    their own training corpus (learning machinery sanity; the real
    distributional evaluation is benchmarks/fig4_strategies.py)."""
    labels = corpus.labels
    majority_acc = max(np.bincount(labels, minlength=3)) / len(labels)
    for s in (
        RuleBasedStrategy().fit(corpus.stats, labels),
        ClassificationStrategy().fit(corpus.stats, labels),
        RegressionStrategy().fit(corpus.stats, corpus.runtimes),
    ):
        res = evaluate_strategy(s, corpus.stats, labels, corpus.runtimes)
        assert res["accuracy"] >= majority_acc - 0.25
