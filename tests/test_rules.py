"""Logical-optimization rules: semantics preservation + effect assertions.

The ground truth for every rule test: the optimized plan must return the same
rows/aggregates as the unoptimized plan (rounding tolerance per the paper's
own §7.4 error bands), while measurably shrinking the model / the scanned
columns — the paper's claims in §4.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.ir import LScan, TableStats, walk
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.core.rules.data_induced import apply_data_induced
from repro.core.rules.predicate_pruning import apply_predicate_pruning
from repro.core.rules.projection_pushdown import apply_projection_pushdown
from repro.relational.engine import Join as PJoin
from repro.relational.engine import execute_plan, walk_plan
from repro.sql.parser import parse_prediction_query
from tests.conftest import train_pipeline


def _count_query(ds, pipe, where=""):
    sql = (
        "SELECT COUNT(*), SUM(pred), AVG(score) FROM "
        f"PREDICT(model='m', data={ds.fact}"
        + "".join(
            f" JOIN {dim} ON {fk} = {dk}" for fk, dim, dk in ds.join_keys
        )
        + ") AS p"
        + (f" WHERE {where}" if where else "")
    )
    return parse_prediction_query(sql, {"m": pipe}, ds.tables)


def _run(q, **opts):
    plan, report = RavenOptimizer(options=OptimizerOptions(**opts)).optimize(q)
    out = execute_plan(plan, DS.tables)
    return {k: np.asarray(v) for k, v in out.columns.items()}, plan, report


DS = None  # set per-test via fixture


@pytest.mark.parametrize("kind", ["dt", "gb", "lr"])
def test_all_rules_preserve_semantics_hospital(hospital, kind):
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, kind)
    q = _count_query(hospital, pipe, where="asthma = 1 AND age >= 40")
    base, _, _ = _run(
        q, predicate_pruning=False, projection_pushdown=False,
        data_induced=False, transform="none",
    )
    for transform in ("none", "sql", "dnn"):
        got, _, _ = _run(q, transform=transform)
        assert abs(got["count_rows"][0] - base["count_rows"][0]) <= max(
            1, 0.005 * base["count_rows"][0]
        )
        np.testing.assert_allclose(
            got["mean_score"], base["mean_score"], rtol=0.02
        )


@pytest.mark.parametrize("kind", ["dt", "gb"])
def test_predicate_pruning_shrinks_trees(hospital, kind):
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, kind)
    q = _count_query(hospital, pipe, where="asthma = 1 AND age >= 70")
    q2 = q.copy()
    apply_predicate_pruning(q2)
    before = sum(
        m.attrs["ensemble"].n_nodes for m in pipe.model_nodes()
    )
    after = sum(
        m.attrs["ensemble"].n_nodes
        for m in q2.predict_nodes()[0].pipeline.model_nodes()
    )
    assert after < before
    # the equality-constrained input became a constant (paper step 1)
    assert "asthma" not in q2.predict_nodes()[0].pipeline.input_names()


def test_predicate_pruning_preserves_rowset(hospital):
    """Pruned pipeline must agree with the original on every row satisfying
    the predicate (not just on aggregate counts)."""
    from repro.ml.pipeline import run_pipeline

    global DS
    DS = hospital
    pipe = train_pipeline(hospital, "dt")
    q = _count_query(hospital, pipe, where="asthma = 1 AND age >= 70")
    q2 = q.copy()
    apply_predicate_pruning(q2)
    pruned = q2.predict_nodes()[0].pipeline
    joined = hospital.joined_columns()
    mask = (joined["asthma"] == 1) & (joined["age"] >= 70)
    rows = {k: joined[k][mask] for k in joined}
    a = run_pipeline(pipe, {k: rows[k] for k in pipe.input_names()})
    b = run_pipeline(pruned, {k: rows[k] for k in pruned.input_names()})
    np.testing.assert_allclose(
        np.asarray(a["score"]).reshape(-1),
        np.asarray(b["score"]).reshape(-1),
        rtol=1e-9,
    )


def test_projection_pushdown_prunes_scan_columns(hospital):
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, "dt")  # depth-6 tree: many unused inputs
    q = _count_query(hospital, pipe)
    q2 = q.copy()
    apply_projection_pushdown(q2)
    scan = [n for n in walk(q2.plan) if isinstance(n, LScan)][0]
    n_all = len(hospital.tables["patients"])
    assert len(scan.columns) < n_all, "pushdown should reach the scan"
    # pruned pipeline inputs match the scanned columns
    assert set(q2.predict_nodes()[0].pipeline.input_names()) <= set(scan.columns)


def test_join_elimination_on_expedia(expedia):
    """If every column of a dim table is projected out, the FK join dies —
    the paper's biggest multi-table win."""
    from repro.ml import LogisticRegression
    from repro.ml.pipeline import fit_pipeline

    global DS
    DS = expedia
    joined = expedia.joined_columns()
    # model over fact-table columns only -> both dim joins must be eliminated
    numeric = [c for c in expedia.numeric if c.startswith("s_")]
    categorical = [c for c in expedia.categorical if c.startswith("s_")]
    pipe = fit_pipeline(
        joined, expedia.label, numeric, categorical,
        LogisticRegression(n_iter=30), categories=expedia.categories(),
    )
    q = _count_query(expedia, pipe)
    base, plan_no, _ = _run(
        q, predicate_pruning=False, projection_pushdown=False,
        data_induced=False, transform="none",
    )
    got, plan_opt, _ = _run(q, transform="none")
    assert sum(isinstance(p, PJoin) for p in walk_plan(plan_no)) == 2
    assert sum(isinstance(p, PJoin) for p in walk_plan(plan_opt)) == 0
    np.testing.assert_allclose(got["count_rows"], base["count_rows"])
    np.testing.assert_allclose(got["mean_score"], base["mean_score"], rtol=1e-4)


def test_data_induced_partition_models(hospital):
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, "dt")
    stats = {
        "patients": TableStats.of(hospital.tables["patients"], partition_col="rcount")
    }
    sql = (
        "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) AS p "
        "WHERE score >= 0.5"
    )
    q = parse_prediction_query(sql, {"m": pipe}, hospital.tables, stats=stats)
    q2 = q.copy()
    apply_data_induced(q2)
    pn = q2.predict_nodes()[0]
    assert pn.partitioned is not None and len(pn.partitioned) == 6
    # per-partition specialized model predictions == original on that partition
    from repro.ml.pipeline import run_pipeline

    joined = hospital.joined_columns()
    ref = run_pipeline(pipe, {k: joined[k] for k in pipe.input_names()})
    for key, spec in pn.partitioned:
        mask = joined["rcount"] == key
        got = run_pipeline(spec, {k: joined[k][mask] for k in spec.input_names()})
        np.testing.assert_allclose(
            np.asarray(got["score"]).reshape(-1),
            np.asarray(ref["score"]).reshape(-1)[mask],
            rtol=1e-9,
        )


def test_data_induced_minmax_prunes_without_partitions(hospital):
    """Global min/max stats alone must already allow pruning branches that
    test outside the observed range."""
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, "dt")
    # fabricate stats narrowing 'age' to >= 60: the tree loses its young side
    stats = {"patients": TableStats.of(hospital.tables["patients"])}
    stats["patients"].columns["age"].min = 60.0
    q = _count_query(hospital, pipe)
    q.stats = stats
    q2 = q.copy()
    apply_data_induced(q2)
    before = sum(m.attrs["ensemble"].n_nodes for m in pipe.model_nodes())
    after = sum(
        m.attrs["ensemble"].n_nodes
        for m in q2.predict_nodes()[0].pipeline.model_nodes()
    )
    assert after < before


def test_output_predicate_leaf_pruning(hospital):
    global DS
    DS = hospital
    pipe = train_pipeline(hospital, "dt")
    q = _count_query(hospital, pipe, where="pred = 1")
    base, _, _ = _run(
        q, predicate_pruning=False, projection_pushdown=False,
        data_induced=False, transform="none",
    )
    got, _, _ = _run(q, transform="none")
    np.testing.assert_allclose(got["count_rows"], base["count_rows"])
    np.testing.assert_allclose(got["mean_score"], base["mean_score"], rtol=1e-6)
