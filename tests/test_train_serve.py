"""Training loop (fault-tolerance wiring) + serve engine behaviour."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.loader import TokenLoader
from repro.distributed import StragglerMonitor
from repro.launch.train import train_loop
from repro.serve import ServeEngine
from repro.configs import reduced_config
from repro.models import build_model


def test_loss_decreases_on_planted_bigrams(tmp_path):
    out = train_loop(
        arch="qwen2-0.5b", steps=30, batch=8, seq=64, lr=2e-3,
        ckpt_dir=None, log_every=100, print_fn=lambda *a: None,
    )
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_checkpoint_resume_continues(tmp_path):
    d = str(tmp_path / "ckpt")
    quiet = lambda *a: None
    train_loop(
        arch="qwen2-0.5b", steps=10, batch=4, seq=32, ckpt_dir=d,
        ckpt_every=5, log_every=100, print_fn=quiet,
    )
    b = train_loop(
        arch="qwen2-0.5b", steps=14, batch=4, seq=32, ckpt_dir=d,
        ckpt_every=5, resume=True, log_every=100, print_fn=quiet,
    )
    assert b["final_step"] == 13
    # resumed run trains only the remaining steps
    assert len(b["losses"]) == 14 - 10


def test_dead_host_shards_reassigned_deterministically():
    mon = StragglerMonitor(n_hosts=4)
    loader = TokenLoader(
        global_batch=8, seq_len=16, vocab=64, n_shards=4, monitor=mon
    )
    full = loader.batch(3, [0, 1, 2, 3])
    mon.mark_dead(2)
    plan = mon.plan_shards(4)
    assert 2 not in plan
    assert sorted(s for ss in plan.values() for s in ss) == [0, 1, 2, 3]
    # batch content is identical no matter which host materializes it
    again = loader.batch(3, sorted(s for ss in plan.values() for s in ss))
    np.testing.assert_array_equal(full["tokens"], again["tokens"])


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor()
    flagged = [mon.record_step(0.1) for _ in range(20)]
    assert not any(flagged)
    assert mon.record_step(3.0)  # 30x median


def test_loader_is_deterministic_across_processes():
    a = TokenLoader(global_batch=4, seq_len=8, vocab=32, seed=5).batch(11)
    b = TokenLoader(global_batch=4, seq_len=8, vocab=32, seed=5).batch(11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = reduced_config("qwen2-0.5b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_serve_engine_batches_and_finishes(tiny_lm):
    model, params = tiny_lm
    eng = ServeEngine(model, params, n_slots=2, cache_len=64)
    reqs = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(5)]
    done = eng.run(max_ticks=200)
    assert len(done) == 5
    for r in reqs:
        assert r.done and len(r.output) == 5


def test_serve_engine_matches_stepwise_oracle(tiny_lm):
    """Engine output == manual prefill+decode with the same padding."""
    model, params = tiny_lm
    eng = ServeEngine(model, params, n_slots=1, cache_len=64)
    prompt = [5, 6, 7]
    r = eng.submit(prompt, max_new_tokens=4)
    eng.run(max_ticks=50)

    P = eng.prefill_len
    toks = np.zeros((1, P), np.int32)
    toks[0, P - len(prompt):] = prompt
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=64)
    )(params, {"tokens": jnp.asarray(toks)})
    out = [int(jnp.argmax(logits, -1)[0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(3):
        lg, caches = model.decode(
            params,
            {"tokens": jnp.asarray([out[-1]], jnp.int32), "lengths": lengths},
            caches,
        )
        out.append(int(jnp.argmax(lg, -1)[0]))
        lengths = lengths + 1
    assert r.output == out


def test_serve_engine_recycles_slots(tiny_lm):
    model, params = tiny_lm
    eng = ServeEngine(model, params, n_slots=2, cache_len=48)
    for i in range(6):
        eng.submit([i + 1], max_new_tokens=3)
    done = eng.run(max_ticks=100)
    assert len(done) == 6  # 6 requests through 2 slots
