"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (spec deliverable f)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import build_model
from repro.train.step import init_opt_state, make_train_step


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.frontend == "vision":
        batch["patches"] = (
            jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.01
        )
    if cfg.family == "encdec":
        batch["frames"] = (
            jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.01
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss is not finite"
    assert float(loss) > 0

    # one full train step: loss must stay finite, params must change
    opt = init_opt_state(model, params)
    step = jax.jit(make_train_step(model, lr=1e-3))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_prefill_decode_consistency(arch):
    """Greedy next-token from prefill must equal a decode step replaying the
    same prefix (KV-cache correctness)."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        batch["patches"] = (
            jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.01
        )
    if cfg.family == "encdec":
        batch["frames"] = (
            jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.float32) * 0.01
        )
    logits, caches = model.prefill(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = {
        "tokens": jnp.argmax(logits, -1).astype(jnp.int32),
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    # decode caches sized for prefill length S need one free slot: rebuild
    # prefill with headroom where supported (dense KV families). The vlm
    # prefill sequence includes the prepended patch embeddings.
    if cfg.family in ("dense", "moe", "vlm"):
        full_S = S + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
        logits, caches = model.prefill(params, batch, cache_len=full_S + 4)
        db["lengths"] = jnp.full((B,), full_S, jnp.int32)
    lg2, c2 = model.decode(params, db, caches)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    assert lg2.shape[0] == B


def test_full_configs_match_spec():
    """The registry's full configs carry the published dimensions."""
    spec = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch, (L, D, H, KH, F, V) in spec.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, D, H, KH, F, V), f"{arch}: {got}"
    assert get_config("arctic-480b").moe_experts == 128
    assert get_config("arctic-480b").moe_top_k == 2
    assert get_config("arctic-480b").moe_dense_residual
    assert get_config("qwen2-moe-a2.7b").moe_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe_top_k == 4
    assert get_config("qwen2-moe-a2.7b").moe_shared_experts == 4
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("qwen2-0.5b").qkv_bias


def test_tp_head_padding_is_exact():
    """repeat-KV + head padding (tp_pad_heads) must be bit-exact: the MHA
    view preserves the GQA q->kv assignment and padded heads are sliced off
    (EXPERIMENTS.md §Roofline — measured, and refuted as a perf win on
    llava, but the transformation itself must stay lossless)."""
    import dataclasses

    cfg0 = reduced_config("llava-next-34b")
    cfg1 = dataclasses.replace(cfg0, tp_pad_heads=8)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {
        "tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
        % cfg0.vocab_size,
        "labels": jnp.ones((B, S), jnp.int32),
        "patches": jnp.ones((B, cfg0.frontend_tokens, cfg0.d_model),
                            jnp.float32) * 0.01,
    }
    assert float(m0.loss(params, batch)) == float(m1.loss(params, batch))
    pb = {k: v for k, v in batch.items() if k != "labels"}
    g0, c0 = m0.prefill(params, pb)
    g1, c1 = m1.prefill(params, pb)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    # caches keep the ORIGINAL kv-head count (expansion is attention-local)
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        assert a.shape == b.shape
