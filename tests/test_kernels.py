"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.tensor.tree2tensor import build_gemm_program, gemm_predict


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("shape", [(2, 128, 4, 64), (1, 256, 8, 32), (3, 64, 6, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, dtype, causal):
    B, S, H, D = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, shape, dtype)
    k = _rand(k2, shape, dtype)
    v = _rand(k3, shape, dtype)
    got = ops.flash_attention_op(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_flash_attention_gqa(kv_heads):
    B, S, H, D = 2, 128, 4, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(k1, (B, S, H, D), jnp.float32)
    k = _rand(k2, (B, S, kv_heads, D), jnp.float32)
    v = _rand(k3, (B, S, kv_heads, D), jnp.float32)
    got = ops.flash_attention_op(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("shape", [(2, 128, 4, 64), (4, 512, 2, 64), (1, 64, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(shape, dtype):
    B, S, KH, D = shape
    H = KH * 2
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(k1, (B, H, D), dtype)
    kc = _rand(k2, (B, S, KH, D), dtype)
    vc = _rand(k3, (B, S, KH, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S, size=B), jnp.int32
    )
    got = ops.decode_attention_op(q, kc, vc, lengths, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("n_estimators,max_depth", [(1, 3), (8, 4), (20, 2)])
def test_tree_gemm_kernel_sweep(hospital, n_estimators, max_depth):
    from repro.ml import GradientBoostingClassifier

    ds = hospital
    joined = ds.joined_columns()
    X = np.stack([joined[c] for c in ds.numeric], 1)
    gb = GradientBoostingClassifier(
        n_estimators=n_estimators, max_depth=max_depth
    ).fit(X, ds.label)
    prog = build_gemm_program(gb.ensemble)
    Xj = jnp.asarray(X[:512], jnp.float32)
    want = gemm_predict(prog, Xj)
    A, B, C, D, V = ops.pad_gemm_program(
        prog.A, prog.B, prog.C, prog.Dcount, prog.V
    )
    got = ops.tree_gemm_op(
        Xj, jnp.asarray(A), jnp.asarray(B), jnp.asarray(C), jnp.asarray(D),
        jnp.asarray(V), base=prog.base, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.kernel_parity
@pytest.mark.parametrize("N", [0, 100, 256, 257])
@pytest.mark.parametrize(
    "n_num,segs",
    [
        (5, (4, 4, 4)),
        (1, (2,)),
        (9, (3, 7, 2, 5)),
        (4, ()),   # numeric-only: no one-hot segments
        (0, (3, 5)),  # categorical-only: no scaler columns
    ],
)
def test_featurize_kernel_sweep(n_num, segs, N):
    """Fused featurize kernel vs the jnp oracle — including row counts that
    are not a multiple of ``block_n`` (internal pad/crop) and zero-width
    numeric/categorical operands."""
    rng = np.random.default_rng(3)
    num = jnp.asarray(rng.normal(size=(N, n_num)), jnp.float32)
    cat = jnp.asarray(
        np.stack([rng.integers(0, s, N) for s in segs], 1)
        if segs else np.zeros((N, 0)),
        jnp.int32,
    )
    offset = jnp.asarray(rng.normal(size=n_num), jnp.float32)
    scale = jnp.asarray(rng.uniform(0.5, 2.0, size=n_num), jnp.float32)
    starts = np.cumsum([0] + list(segs))[:-1]
    cat_values = jnp.asarray(
        np.concatenate([np.arange(s) for s in segs] or [np.zeros(0)]),
        jnp.int32,
    )
    cat_segments = tuple(
        (int(s), int(l)) for s, l in zip(starts, segs)
    )
    got = ops.featurize_op(
        num, cat, offset, scale, cat_values, cat_segments, interpret=True
    )
    want = ref.featurize_ref(num, cat, offset, scale, cat_values, cat_segments)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    assert got.shape == (N, n_num + sum(segs))


@pytest.mark.kernel_parity
def test_featurize_kernel_bitwise_vs_host_featurization():
    """The fused kernel is *bitwise* identical to the host numpy
    featurization path for scaler + one-hot columns (both are elementwise
    f32); this is what lets split plans keep host-path semantics."""
    rng = np.random.default_rng(7)
    N, n_num, segs = 300, 6, (4, 9)
    num_np = rng.normal(size=(N, n_num)).astype(np.float32)
    cat_np = np.stack([rng.integers(0, s, N) for s in segs], 1).astype(np.int32)
    offset = rng.normal(size=n_num).astype(np.float32)
    scale = rng.uniform(0.5, 2.0, size=n_num).astype(np.float32)
    starts = np.cumsum([0] + list(segs))[:-1]
    cat_values = np.concatenate([np.arange(s) for s in segs]).astype(np.int32)
    cat_segments = tuple((int(s), int(l)) for s, l in zip(starts, segs))

    got = np.asarray(
        ops.featurize_op(
            jnp.asarray(num_np), jnp.asarray(cat_np), jnp.asarray(offset),
            jnp.asarray(scale), jnp.asarray(cat_values), cat_segments,
            interpret=True,
        )
    )
    scaled = (num_np - offset[None, :]) * scale[None, :]
    onehots = [
        (cat_np[:, j : j + 1] == cat_values[s : s + l][None, :]).astype(
            np.float32
        )
        for j, (s, l) in enumerate(cat_segments)
    ]
    want = np.concatenate([scaled, *onehots], axis=1)
    assert np.array_equal(got.view(np.uint32), want.view(np.uint32))


def test_tree_gemm_padding_is_inert(hospital):
    """MXU padding must not change scores (the pad proof in ops.py)."""
    from repro.ml import DecisionTreeClassifier

    ds = hospital
    joined = ds.joined_columns()
    X = np.stack([joined[c] for c in ds.numeric], 1)
    dt = DecisionTreeClassifier(max_depth=5).fit(X, ds.label)
    prog = build_gemm_program(dt.ensemble)
    Xj = jnp.asarray(X[:128], jnp.float32)
    want = gemm_predict(prog, Xj)
    for align in (8, 64, 128, 256):
        A, B, C, D, V = ops.pad_gemm_program(
            prog.A, prog.B, prog.C, prog.Dcount, prog.V, align=align
        )
        got = ops.tree_gemm_op(
            Xj, jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
            jnp.asarray(D), jnp.asarray(V), base=prog.base, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
