"""The session front door: connect / sql / builder / prepare / explain /
params / serve — plus the satellite guarantees (ne end-to-end, TensorOp
content fingerprints, corpus measurement through the plan cache)."""
from __future__ import annotations

import numpy as np
import pytest

import repro as raven
from repro.core.optimizer import OptimizerOptions, RavenOptimizer
from repro.data.datasets import make_hospital
from repro.errors import (
    RavenError,
    SQLSyntaxError,
    UnboundParameterError,
    UnknownColumnError,
    UnknownModelError,
    UnknownParameterError,
    UnknownTableError,
)
from repro.ml.pipeline import run_pipeline
from repro.relational.engine import PLAN_CACHE_STATS, plan_fingerprint
from tests.conftest import train_pipeline


@pytest.fixture()
def db(hospital, hospital_gb):
    sess = raven.connect(hospital.tables, stats="auto")
    sess.register_model("m", hospital_gb)
    return sess


def _scores(hospital, pipe) -> np.ndarray:
    out = run_pipeline(pipe, hospital.joined_columns())
    return np.asarray(out[pipe.outputs[0]]).reshape(-1)


# ---------------------------------------------------------------------------
# Query construction: SQL text and fluent builder are one front door
# ---------------------------------------------------------------------------


def test_sql_and_builder_fingerprint_identical(db):
    sql = db.sql(
        "SELECT COUNT(*), AVG(score) FROM PREDICT(model='m', data=patients) "
        "AS p WHERE asthma = 1 AND score >= 0.6"
    )
    built = (
        db.table("patients").predict("m")
        .where("asthma = 1").where("score", ">=", 0.6)
        .select("COUNT(*)", "AVG(score)")
    )
    assert sql.fingerprint() == built.fingerprint()


def test_sql_and_builder_fingerprint_identical_with_joins(expedia):
    pipe = train_pipeline(expedia, "lr")
    db = raven.connect(expedia.tables, stats=None)
    db.register_model("m", pipe)
    sql = db.sql(
        "SELECT COUNT(*) FROM PREDICT(model='m', data=searches "
        "JOIN hotels ON hotel_id = hotel_id "
        "JOIN destinations ON dest_id = dest_id) AS p "
        "WHERE s_cat0 = 3 AND score >= :t"
    )
    built = (
        db.table("searches")
        .join("hotels", on="hotel_id")
        .join("destinations", on=("dest_id", "dest_id"))
        .predict("m")
        .where("s_cat0 = 3").where("score >= :t")
        .select("COUNT(*)")
    )
    assert sql.fingerprint() == built.fingerprint()
    assert sql.param_names() == {"t"}


def test_builder_string_literal_matches_sql(db):
    sql = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE blood_type = 'A'"
    )
    built = db.table("patients").predict("m").where("blood_type", "=", "A")
    assert sql.fingerprint() == built.fingerprint()


def test_param_name_not_value_in_fingerprint(db):
    with_param = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    )
    with_const = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.6"
    )
    assert with_param.fingerprint() != with_const.fingerprint()
    # prepared under two different bindings: identical physical fingerprint
    a = with_param.prepare(transform="sql", params={"t": 0.2})
    b = with_param.prepare(transform="sql", params={"t": 0.8})
    assert a.fingerprint == b.fingerprint


# ---------------------------------------------------------------------------
# Prepare + execute + re-bind
# ---------------------------------------------------------------------------


def test_prepared_query_executes_correctly(db, hospital, hospital_gb):
    scores = _scores(hospital, hospital_gb)
    prep = db.sql(
        "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) "
        "WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.5})
    assert float(prep()["count_rows"][0]) == (scores >= 0.5).sum()


def test_rebind_reuses_compiled_plan_zero_traces(db, hospital, hospital_gb):
    scores = _scores(hospital, hospital_gb)
    prep = db.sql(
        "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) "
        "WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.3})
    n_lo = float(prep()["count_rows"][0])
    traces_before = prep.compiled.traces
    cache_traces_before = PLAN_CACHE_STATS.traces
    prep.bind(t=0.9)
    n_hi = float(prep()["count_rows"][0])
    assert prep.compiled.traces == traces_before  # zero new XLA traces
    assert PLAN_CACHE_STATS.traces == cache_traces_before
    assert n_lo == (scores >= 0.3).sum()
    assert n_hi == (scores >= 0.9).sum()
    assert n_lo > n_hi


def test_one_shot_on_fresh_batch(db, hospital_gb):
    batch = make_hospital(333, seed=7).tables["patients"]
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.5})
    out = prep(batch)
    oracle = np.asarray(
        run_pipeline(hospital_gb, {k: np.asarray(v) for k, v in batch.items()})[
            hospital_gb.outputs[0]
        ]
    ).reshape(-1)
    assert len(out["score"]) == (oracle >= 0.5).sum()


# ---------------------------------------------------------------------------
# EXPLAIN
# ---------------------------------------------------------------------------


def test_explain_renders_runtimes_projections_and_notes(db):
    prep = db.sql(
        "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) "
        "WHERE asthma = 1 AND score >= :t"
    ).prepare(transform="sql", params={"t": 0.6})
    text = prep.explain()
    assert "predict[0] -> sql" in text            # chosen runtime
    assert "logical plan" in text and "physical plan" in text
    assert "Scan[patients]" in text
    assert "reads" in text and "columns" in text  # pushed projections
    assert "logit" in text                        # rewritten threshold
    assert ":t" in text                           # param binding shown
    assert any(n in text for n in prep.report.notes)


def test_explain_udf_runtime(db):
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients)"
    ).prepare(transform="none")
    text = prep.explain()
    assert "predict[0] -> none" in text
    assert "MLUdf" in text and "host boundary" in text


# ---------------------------------------------------------------------------
# Serving through the session
# ---------------------------------------------------------------------------


def test_serve_submit_flush_matches_one_shot(db):
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.5}).serve(name="risk")
    b1 = make_hospital(200, seed=11).tables["patients"]
    b2 = make_hospital(900, seed=12).tables["patients"]
    r1, r2 = prep.submit(b1), prep.submit(b2)
    done = db.flush()
    assert {id(r) for r in done} == {id(r1), id(r2)}
    assert r1.done and r2.done
    one = prep(b1)
    np.testing.assert_allclose(
        np.sort(one["score"]), np.sort(r1.result["score"]), atol=1e-5
    )


def test_serve_rebind_is_fingerprint_stable_and_trace_free(db):
    prep = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    ).prepare(transform="sql", params={"t": 0.2}).serve(name="risk")
    batch = make_hospital(256, seed=13).tables["patients"]
    r_lo = prep.submit(batch)
    db.flush()
    reg = db.server.queries["risk"]
    fp_before = reg.compiled.fingerprint
    traces_before = db.server.recompiles()
    prep.bind(t=0.95)  # propagates into the server-registered query
    r_hi = prep.submit(batch)  # same shape bucket
    db.flush()
    assert db.server.queries["risk"].compiled.fingerprint == fp_before
    assert db.server.recompiles() == traces_before
    assert len(r_hi.result["score"]) < len(r_lo.result["score"])


def test_submit_before_serve_raises(db):
    prep = db.sql("SELECT * FROM PREDICT(model='m', data=patients)").prepare(
        transform="sql"
    )
    with pytest.raises(RavenError, match="not served"):
        prep.submit(make_hospital(64, seed=3).tables["patients"])


# ---------------------------------------------------------------------------
# Typed error paths (SQL frontend + parameters)
# ---------------------------------------------------------------------------


def test_unknown_model_raises_typed_error(db):
    with pytest.raises(UnknownModelError, match="nope"):
        db.sql("SELECT * FROM PREDICT(model='nope', data=patients)")


def test_unknown_table_raises_typed_error(db):
    with pytest.raises(UnknownTableError, match="nosuch"):
        db.sql("SELECT * FROM PREDICT(model='m', data=nosuch)")
    with pytest.raises(UnknownTableError, match="missing_dim"):
        db.sql(
            "SELECT * FROM PREDICT(model='m', data=patients "
            "JOIN missing_dim ON asthma = asthma)"
        )
    with pytest.raises(UnknownTableError):
        db.table("nosuch")


def test_unknown_column_raises_typed_error(db):
    with pytest.raises(UnknownColumnError, match="not_a_col"):
        db.sql(
            "SELECT * FROM PREDICT(model='m', data=patients) "
            "WHERE not_a_col = 1"
        )


def test_malformed_predict_clause_raises_typed_error(db):
    for bad in [
        "SELECT * FROM PREDICT(model='m' data=patients)",   # missing comma
        "SELECT * FROM PREDICT(data=patients)",             # missing model
        "SELECT * FROM PREDICT(model='m', data=patients",   # unclosed paren
        "SELECT * FROM patients",                           # no PREDICT
    ]:
        with pytest.raises(SQLSyntaxError) as e:
            db.sql(bad)
        assert str(e.value)  # message-bearing


def test_unbound_and_unknown_params_raise(db):
    q = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= :t"
    )
    with pytest.raises(UnboundParameterError, match="t"):
        q.prepare(transform="sql")
    with pytest.raises(UnknownParameterError, match="zzz"):
        q.prepare(transform="sql", params={"t": 0.5, "zzz": 1.0})
    prep = q.prepare(transform="sql", params={"t": 0.5})
    with pytest.raises(UnknownParameterError, match="zzz"):
        prep.bind(zzz=3.0)


# ---------------------------------------------------------------------------
# Satellite: <> / != end-to-end
# ---------------------------------------------------------------------------


def test_ne_operator_end_to_end(db, hospital, hospital_gb):
    scores = _scores(hospital, hospital_gb)
    asthma = hospital.tables["patients"]["asthma"]
    for op in ("<>", "!="):
        prep = db.sql(
            f"SELECT COUNT(*) FROM PREDICT(model='m', data=patients) "
            f"WHERE asthma {op} 1 AND score >= 0.5"
        ).prepare(transform="sql")
        got = float(prep()["count_rows"][0])
        assert got == ((asthma != 1) & (scores >= 0.5)).sum()


def test_ne_does_not_block_sibling_constraint_pruning(db):
    # 'asthma = 1' must still prune the model even with a ne-conjunct present
    q = db.sql(
        "SELECT COUNT(*) FROM PREDICT(model='m', data=patients) "
        "WHERE asthma = 1 AND diabetes <> 1"
    )
    full_inputs = len(db.models["m"].inputs)
    plan, _ = RavenOptimizer(options=OptimizerOptions(transform="none")).optimize(q.ir)
    from repro.relational.engine import MLUdf, walk_plan

    udf = next(p for p in walk_plan(plan) if isinstance(p, MLUdf))
    assert len(udf.pipeline.inputs) < full_inputs  # asthma folded to constant


# ---------------------------------------------------------------------------
# Satellite: TensorOp canonical content fingerprints
# ---------------------------------------------------------------------------


def test_mltodnn_plans_fingerprint_stably(db):
    q = db.sql(
        "SELECT * FROM PREDICT(model='m', data=patients) WHERE score >= 0.5"
    )
    opt = lambda: RavenOptimizer(  # noqa: E731
        options=OptimizerOptions(transform="dnn")
    ).optimize(q.ir)[0]
    pins_a, pins_b = [], []
    fp_a = plan_fingerprint(opt(), pins=pins_a)
    fp_b = plan_fingerprint(opt(), pins=pins_b)
    assert fp_a == fp_b            # content-stable across lowerings
    assert not pins_a and not pins_b  # nothing identity-hashed -> persistable


def test_tensor_compilation_carries_content_token(hospital_gb):
    from repro.tensor.compile import compile_pipeline_tensor

    a = compile_pipeline_tensor(hospital_gb)
    b = compile_pipeline_tensor(hospital_gb.copy())
    assert a.fn.__fingerprint_token__ == b.fn.__fingerprint_token__


# ---------------------------------------------------------------------------
# Satellite: corpus measurement rides the compiled-plan cache
# ---------------------------------------------------------------------------


def test_corpus_measure_reuses_compiled_plans(hospital_lr):
    from repro.core.corpus import _measure

    rng = np.random.default_rng(0)
    t_first = _measure(hospital_lr, 256, rng)
    traces_before = PLAN_CACHE_STATS.traces
    t_second = _measure(hospital_lr, 256, rng)
    assert PLAN_CACHE_STATS.traces == traces_before  # zero re-traces
    assert np.all(np.isfinite(t_first)) and np.all(np.isfinite(t_second))
